//! The driver process: owns all mutable training state (weights,
//! optimizer, rate controller, evaluation, the run report), admits
//! workers over the control channel, broadcasts per-epoch plans, reduces
//! gradients in rank order, and — the point of this module — survives
//! worker crashes.
//!
//! # Failure model
//!
//! A worker is declared dead when its control connection reaches EOF /
//! errors, or when its heartbeats go silent for `heartbeat_timeout_ms`.
//! Recovery then proceeds:
//!
//! 1. **Pause**: broadcast [`Ctrl::Abort`] so survivors blocked in a
//!    halo exchange error out of the doomed epoch instead of timing out.
//! 2. **Re-admit**: wait for the dead rank(s) to rejoin — respawned by
//!    the driver itself (`spawn_workers`) or by an external supervisor.
//! 3. **Restore**: reassemble weights + optimizer from the last *fully
//!    acknowledged* checkpoint shard set (kept in memory; the on-disk
//!    shards serve whole-cluster restarts via `--resume`), truncate the
//!    run report back to the restore point.
//! 4. **Rewire**: `Welcome` the rejoined ranks (full peer directory),
//!    `Rewind` the survivors (reset data plane, reconnect only the
//!    changed ranks), then resume broadcasting plans.
//!
//! Replayed epochs are bitwise identical to the originals: under
//! open-loop schedules all per-message state is key-derived, and
//! closed-loop controllers snapshot their mutable state into rank 0's
//! residual slot of every shard set, so a rewound run replans from
//! exactly the checkpointed controller rather than re-observing the
//! replayed epochs twice.

use super::protocol::{read_ctrl, write_ctrl, Ctrl};
use super::{admission_hash, build_controller, DistContext};
use crate::compress::{LayerFeedback, LinkCell, RateController};
use crate::config::TrainConfig;
use crate::coordinator::checkpoint::{CheckpointShard, ShardSet};
use crate::coordinator::eval::FullGraphEval;
use crate::coordinator::trainer::{observe_epoch, plan_epoch, push_record, LinkRates};
use crate::engine::Weights;
use crate::metrics::{LinkTraffic, RunReport};
use crate::optim::Optimizer;
use crate::Result;
use std::collections::BTreeMap;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How `run_driver` is launched.
pub struct DriverOptions {
    /// pre-bound control listener (tests bind `127.0.0.1:0` themselves);
    /// `None` binds `cfg.driver_addr`
    pub listener: Option<TcpListener>,
    /// spawn `varco worker --rank R` child processes for every rank and
    /// respawn them after crashes; off when an external supervisor (or a
    /// test harness) owns the worker processes
    pub spawn_workers: bool,
    /// restore from the on-disk shard set in `cfg.ckpt_dir` before
    /// training (whole-cluster restart)
    pub resume: bool,
}

impl Default for DriverOptions {
    fn default() -> DriverOptions {
        DriverOptions { listener: None, spawn_workers: false, resume: false }
    }
}

/// What a completed driver run hands back.
pub struct DistRun {
    pub report: RunReport,
    /// final model weights (bitwise identical to the equivalent
    /// in-process run; pinned by `tests/dist_equivalence.rs`)
    pub weights: Weights,
}

enum Event {
    Join { conn: u64, rank: usize, data_addr: String, config_hash: u64, writer: TcpStream },
    Msg { conn: u64, rank: usize, ctrl: Ctrl },
    Dead { conn: u64, rank: usize },
}

/// Read one control connection: first frame must be a Join, then relay
/// every message until EOF/error, which becomes a Dead event.
fn monitor(mut stream: TcpStream, conn: u64, tx: Sender<Event>) {
    let rank = match read_ctrl(&mut stream) {
        Ok(Some(Ctrl::Join { rank, data_addr, config_hash })) => {
            let writer = match stream.try_clone() {
                Ok(w) => w,
                Err(_) => return,
            };
            if tx.send(Event::Join { conn, rank, data_addr, config_hash, writer }).is_err() {
                return;
            }
            rank
        }
        // not a worker (e.g. the shutdown self-wake): drop silently
        _ => return,
    };
    loop {
        match read_ctrl(&mut stream) {
            Ok(Some(ctrl)) => {
                if tx.send(Event::Msg { conn, rank, ctrl }).is_err() {
                    return;
                }
            }
            Ok(None) | Err(_) => {
                let _ = tx.send(Event::Dead { conn, rank });
                return;
            }
        }
    }
}

struct Slot {
    conn: u64,
    writer: TcpStream,
    data_addr: String,
}

/// Why an epoch (or ack collection) could not complete.
enum Interrupt {
    /// one or more workers died; `Driver::recover` takes over
    Dead,
    Fatal(crate::Error),
}

type Phase<T> = std::result::Result<T, Interrupt>;

fn fatal<T>(e: crate::Error) -> Phase<T> {
    Err(Interrupt::Fatal(e))
}

/// One epoch's historical-cache activity, merged across ranks.
#[derive(Default)]
struct HistEpoch {
    hits: u64,
    misses: u64,
    refresh_rows: u64,
    /// histogram: `ages[a]` = boundary rows served or refreshed at age
    /// `a` epochs since their last refresh
    ages: Vec<u64>,
}

struct Driver<'a> {
    cfg: &'a TrainConfig,
    ctx: DistContext,
    layer_dims: Vec<(usize, usize)>,
    hash: u64,
    rx: Receiver<Event>,
    slots: Vec<Option<Slot>>,
    /// admitted but not yet sent a Welcome (fresh or re-admitted ranks)
    needs_welcome: Vec<bool>,
    last_seen: Vec<Instant>,
    eval: FullGraphEval,
    weights: Weights,
    optimizer: Box<dyn Optimizer>,
    controller: Box<dyn RateController>,
    report: RunReport,
    bytes_cum: usize,
    /// per-epoch stale-skip deltas; truncated on rewind so replays don't
    /// double-count
    stale_by_epoch: Vec<u64>,
    /// per-epoch per-link cells merged rank-order from worker outcomes;
    /// truncated on rewind alongside `stale_by_epoch`
    links_by_epoch: Vec<Vec<LinkCell>>,
    /// per-epoch historical-cache deltas merged across ranks; truncated
    /// on rewind alongside `stale_by_epoch`
    hist_by_epoch: Vec<HistEpoch>,
    /// resolved sampling config (`mode=sampled`); the driver only needs
    /// it for the per-epoch loss normalizer — workers rebuild the full
    /// batch view themselves from (config, seed, epoch)
    sampling: Option<crate::graph::SamplingConfig>,
    /// replay-affecting cache resets caused by crash recovery: counted
    /// per dead rank whenever stale replay or historical caching is on
    stale_cache_resets: usize,
    /// most recent per-link rate plan (link-aware controllers only),
    /// surfaced as `RunReport::link_rates`
    last_links: Option<LinkRates>,
    restarts: usize,
    recovered_epochs: usize,
    heartbeat_timeouts: usize,
    worker_last_ckpt: Vec<Option<usize>>,
    /// the last shard set every worker acknowledged, kept in memory so
    /// recovery never depends on on-disk consistency mid-run
    last_shards: Option<Vec<CheckpointShard>>,
    children: Vec<Option<Child>>,
    /// (exe, resolved config path) for (re)spawning workers
    spawn_cmd: Option<(PathBuf, PathBuf)>,
    ctrl_addr: std::net::SocketAddr,
    closing: Arc<AtomicBool>,
}

const POLL: Duration = Duration::from_millis(50);

impl<'a> Driver<'a> {
    fn q(&self) -> usize {
        self.ctx.q
    }

    fn hb_timeout(&self) -> Duration {
        Duration::from_millis(self.cfg.heartbeat_timeout_ms)
    }

    /// Window to wait for a dead rank to reconnect during recovery (or
    /// for the initial fleet to join).
    fn join_deadline(&self) -> Instant {
        Instant::now() + Duration::from_millis(self.cfg.connect_timeout_ms) + Duration::from_secs(10)
    }

    /// Pull one event and apply connection bookkeeping.  Returns the
    /// message events the caller's phase must interpret; Join/Dead/
    /// Heartbeat are absorbed here.  `Ok(None)` = nothing arrived within
    /// `timeout` AND every queued heartbeat has been folded in, so a
    /// staleness check right after is sound.
    fn pump(&mut self, timeout: Duration) -> Result<Option<(usize, Ctrl)>> {
        match self.rx.recv_timeout(timeout) {
            Ok(Event::Join { conn, rank, data_addr, config_hash, writer }) => {
                if rank >= self.q() {
                    eprintln!("[varco driver] rejecting join from out-of-range rank {rank}");
                    return Ok(None);
                }
                if config_hash != self.hash {
                    eprintln!(
                        "[varco driver] rejecting rank {rank}: config hash {config_hash:#x} != \
                         ours {:#x} (the worker was started with a different config)",
                        self.hash
                    );
                    return Ok(None); // dropping `writer` closes the connection
                }
                self.slots[rank] = Some(Slot { conn, writer, data_addr });
                self.needs_welcome[rank] = true;
                self.last_seen[rank] = Instant::now();
                Ok(None)
            }
            Ok(Event::Msg { conn, rank, ctrl }) => {
                match &self.slots[rank] {
                    Some(s) if s.conn == conn => {
                        self.last_seen[rank] = Instant::now();
                        if matches!(ctrl, Ctrl::Heartbeat { .. }) {
                            Ok(None)
                        } else {
                            Ok(Some((rank, ctrl)))
                        }
                    }
                    // stale connection generation: discard
                    _ => Ok(None),
                }
            }
            Ok(Event::Dead { conn, rank }) => {
                if rank < self.q() {
                    if let Some(s) = &self.slots[rank] {
                        if s.conn == conn {
                            self.slots[rank] = None;
                        }
                    }
                }
                Ok(None)
            }
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                anyhow::bail!("driver event channel closed (accept thread died)")
            }
        }
    }

    /// Declare heartbeat-silent live ranks dead.  Only called right after
    /// an empty `pump`, so queued heartbeats have been folded in.
    fn check_stale(&mut self) {
        let timeout = self.hb_timeout();
        for r in 0..self.q() {
            if self.slots[r].is_some() && self.last_seen[r].elapsed() > timeout {
                eprintln!(
                    "[varco driver] rank {r}: no heartbeat for {:?}, declaring dead",
                    timeout
                );
                self.heartbeat_timeouts += 1;
                self.slots[r] = None;
            }
        }
    }

    fn all_alive(&self) -> bool {
        self.slots.iter().all(|s| s.is_some())
    }

    /// True while every rank is connected AND fully admitted.  A rank can
    /// be connected yet `needs_welcome` when a crashed worker rejoined
    /// before its old connection's Dead event was pumped — the epoch in
    /// flight is doomed either way, so both conditions interrupt it.
    fn fleet_intact(&self) -> bool {
        self.all_alive() && !self.needs_welcome.iter().any(|&w| w)
    }

    /// Send to one live rank; a failed write is a death.
    fn send_to(&mut self, rank: usize, msg: &Ctrl) {
        if let Some(slot) = &mut self.slots[rank] {
            if write_ctrl(&mut slot.writer, msg).is_err() {
                self.slots[rank] = None;
            }
        }
    }

    fn broadcast(&mut self, msg: &Ctrl) {
        for r in 0..self.q() {
            self.send_to(r, msg);
        }
    }

    /// Wait until every rank is admitted, then Welcome the fresh ones and
    /// collect Ready (from welcomed ranks) / RewindAck (from survivors,
    /// when `rewind_to` is set).  Used both at startup (all ranks fresh)
    /// and during recovery.  Returns `Interrupt::Dead` if a rank dies
    /// mid-barrier.
    fn admission_barrier(&mut self, resume_epoch: usize, rewind_survivors: bool) -> Phase<()> {
        let deadline = self.join_deadline();
        while !self.all_alive() {
            if Instant::now() > deadline {
                return fatal(anyhow::anyhow!(
                    "workers {:?} did not (re)join within the admission window",
                    (0..self.q()).filter(|&r| self.slots[r].is_none()).collect::<Vec<_>>()
                ));
            }
            // stray epoch results / acks from before a death are binned here
            if let Err(e) = self.pump(POLL) {
                return fatal(e);
            }
        }
        let peers: Vec<(usize, String)> = (0..self.q())
            .map(|r| (r, self.slots[r].as_ref().expect("all alive").data_addr.clone()))
            .collect();
        let changed: Vec<(usize, String)> =
            peers.iter().filter(|(r, _)| self.needs_welcome[*r]).cloned().collect();
        let mut awaiting_ready = vec![false; self.q()];
        for r in 0..self.q() {
            if self.needs_welcome[r] {
                awaiting_ready[r] = true;
                self.send_to(r, &Ctrl::Welcome { resume_epoch, peers: peers.clone() });
            } else if rewind_survivors {
                self.send_to(r, &Ctrl::Rewind { resume_epoch, peers: changed.clone() });
            }
        }
        let mut ok: Vec<bool> = (0..self.q())
            .map(|r| !awaiting_ready[r] && !rewind_survivors)
            .collect();
        let ack_deadline = self.join_deadline();
        while !ok.iter().all(|&b| b) {
            // a rank dying mid-barrier — or dying and rejoining so fast
            // that only its unwelcomed replacement is visible — restarts
            // the whole recovery round
            let rejoined_unwelcomed =
                (0..self.q()).any(|r| self.needs_welcome[r] && !awaiting_ready[r]);
            if !self.all_alive() || rejoined_unwelcomed {
                return Err(Interrupt::Dead);
            }
            if Instant::now() > ack_deadline {
                return fatal(anyhow::anyhow!("admission barrier timed out waiting for acks"));
            }
            match self.pump(POLL) {
                Err(e) => return fatal(e),
                Ok(None) => self.check_stale(),
                Ok(Some((rank, Ctrl::Ready { rank: r2 }))) if rank == r2 => ok[rank] = true,
                Ok(Some((rank, Ctrl::RewindAck { rank: r2 }))) if rank == r2 => ok[rank] = true,
                Ok(Some(_)) => {} // stray pre-death message: discard
            }
        }
        self.needs_welcome.iter_mut().for_each(|w| *w = false);
        Ok(())
    }

    /// One epoch: broadcast the plan, collect every rank's outcome,
    /// reduce gradients in rank order, step the optimizer, close the
    /// controller loop, and append the epoch record.
    fn run_epoch(&mut self, epoch: usize) -> Phase<()> {
        let t0 = Instant::now();
        let plan = plan_epoch(self.controller.as_ref(), epoch, self.layer_dims.len(), self.q());
        if plan.links.is_some() {
            self.last_links = plan.links.clone();
        }
        let flat_w = self.weights.flatten();
        self.broadcast(&Ctrl::Plan {
            epoch,
            fwd: plan.fwd.clone(),
            bwd: plan.bwd.clone(),
            nominal: plan.nominal,
            feedback: plan.feedback,
            local_norm: plan.local_norm,
            links: plan.links.as_ref().map(|l| l.rates.clone()).unwrap_or_default(),
            weights: flat_w,
        });
        if !self.fleet_intact() {
            return Err(Interrupt::Dead);
        }

        // collect one outcome per rank; on a worker-reported error, hold
        // a grace window first — the error is usually collateral of a
        // peer's death (its link went down), and the death event is what
        // should drive recovery, not the collateral
        let mut outs: Vec<Option<Ctrl>> = (0..self.q()).map(|_| None).collect();
        let mut worker_error: Option<(usize, String, Instant)> = None;
        while outs.iter().any(|o| o.is_none()) {
            if !self.fleet_intact() {
                return Err(Interrupt::Dead);
            }
            if let Some((rank, msg, since)) = &worker_error {
                if since.elapsed() > self.hb_timeout() {
                    return fatal(anyhow::anyhow!("worker {rank} failed epoch {epoch}: {msg}"));
                }
            }
            match self.pump(POLL) {
                Err(e) => return fatal(e),
                Ok(None) => self.check_stale(),
                Ok(Some((rank, Ctrl::Outcome { epoch: e, error: Some(msg), .. })))
                    if e == epoch =>
                {
                    if worker_error.is_none() {
                        worker_error = Some((rank, msg, Instant::now()));
                    }
                }
                Ok(Some((rank, out @ Ctrl::Outcome { .. }))) => {
                    if let Ctrl::Outcome { epoch: e, rank: r2, .. } = &out {
                        if *e == epoch && *r2 == rank {
                            outs[rank] = Some(out);
                        }
                        // stale epoch outcomes (pre-recovery stragglers): discard
                    }
                }
                Ok(Some(_)) => {} // stray ack: discard
            }
        }

        // ---- server step (rank-order reduction == the in-process order) ----
        let param_count = self.weights.param_count();
        let mut grad_acc = vec![0.0f32; param_count];
        let mut loss_weighted = 0.0f32;
        let mut epoch_bytes: usize = 0;
        let mut stale_delta: u64 = 0;
        let mut hist_delta = HistEpoch::default();
        let mut cells: Vec<Vec<LayerFeedback>> = Vec::with_capacity(self.q());
        // merge per-link cells across ranks; the BTreeMap gives the same
        // canonical (from, to) order the in-process ledger diff produces
        let mut link_map: BTreeMap<(usize, usize), (usize, usize)> = BTreeMap::new();
        for (rank, out) in outs.into_iter().enumerate() {
            let Some(Ctrl::Outcome {
                loss_weighted: lw,
                grads,
                feedback,
                bytes,
                stale_skipped,
                hist_hits,
                hist_misses,
                hist_refresh_rows,
                hist_ages,
                links,
                ..
            }) = out
            else {
                unreachable!("collected above");
            };
            if grads.len() != param_count {
                return fatal(anyhow::anyhow!(
                    "worker {rank} returned {} gradient floats, model has {param_count}",
                    grads.len()
                ));
            }
            for (a, g) in grad_acc.iter_mut().zip(&grads) {
                *a += g;
            }
            loss_weighted += lw;
            epoch_bytes += bytes as usize;
            stale_delta += stale_skipped;
            hist_delta.hits += hist_hits;
            hist_delta.misses += hist_misses;
            hist_delta.refresh_rows += hist_refresh_rows;
            if hist_ages.len() > hist_delta.ages.len() {
                hist_delta.ages.resize(hist_ages.len(), 0);
            }
            for (slot, a) in hist_delta.ages.iter_mut().zip(&hist_ages) {
                *slot += a;
            }
            for c in links {
                let e = link_map.entry((c.from, c.to)).or_insert((0, 0));
                e.0 += c.bytes;
                e.1 += c.msgs;
            }
            cells.push(feedback);
        }
        let link_cells: Vec<LinkCell> = link_map
            .into_iter()
            .map(|((from, to), (bytes, msgs))| LinkCell { from, to, bytes, msgs })
            .collect();
        // sampled mode: every rank normalized its local loss by this
        // epoch's batch size, so the driver must match — draw_batch is a
        // pure function of (split, batch_size, seed, epoch), identical to
        // what each worker's view used
        let total_train = match &self.sampling {
            Some(sc) => (crate::graph::sample::draw_batch(
                &self.ctx.store.split().train,
                sc.batch_size,
                self.cfg.seed,
                epoch,
            )
            .len() as f32)
                .max(1.0),
            None => self.ctx.setup.total_train,
        };
        let loss = loss_weighted / total_train;
        // weight-sync accounting: same constant charge as the in-process
        // ledger (gradients up, weights down, per worker)
        let wbytes = param_count * 4;
        epoch_bytes += 2 * self.q() * wbytes;
        self.bytes_cum += epoch_bytes;
        self.stale_by_epoch.push(stale_delta);
        self.hist_by_epoch.push(hist_delta);
        // same conditional as the in-process trainer, so both closed-loop
        // paths hand the controller identical observations
        let fb_links = if plan.feedback && self.controller.link_aware() {
            link_cells.clone()
        } else {
            Vec::new()
        };
        self.links_by_epoch.push(link_cells);

        let mut flat = self.weights.flatten();
        self.optimizer.step(&mut flat, &grad_acc);
        self.weights.set_from_flat(&flat);
        observe_epoch(
            self.controller.as_mut(),
            &plan,
            epoch,
            epoch_bytes,
            cells.iter().map(|c| c.as_slice()),
            fb_links,
        );
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        if let Err(e) = push_record(
            &mut self.report,
            &self.eval,
            &self.weights,
            self.cfg.eval_every,
            self.cfg.epochs,
            plan.nominal,
            self.bytes_cum,
            epoch,
            loss,
            wall_ms,
        ) {
            return fatal(e);
        }
        Ok(())
    }

    /// Ship per-rank shards after `epoch` and wait for every ack; only a
    /// fully acknowledged set becomes the recovery point.
    fn checkpoint(&mut self, epoch: usize) -> Phase<()> {
        // rank 0's residual slot carries the controller snapshot; workers
        // hold no controller state, so the other slots stay empty
        let mut residuals = vec![Vec::new(); self.q()];
        residuals[0] = self.controller.snapshot();
        let shards = ShardSet::make_shards(
            &self.ctx.spec,
            &self.weights.flatten(),
            &self.optimizer.state(),
            &residuals,
            epoch,
            self.cfg.seed,
            self.q(),
        );
        for (r, s) in shards.iter().enumerate() {
            self.send_to(r, &Ctrl::Checkpoint { epoch, shard: s.to_bytes() });
        }
        let mut acked = vec![false; self.q()];
        let deadline = Instant::now() + self.hb_timeout() + Duration::from_secs(30);
        while !acked.iter().all(|&a| a) {
            if !self.fleet_intact() {
                return Err(Interrupt::Dead);
            }
            if Instant::now() > deadline {
                return fatal(anyhow::anyhow!("checkpoint acks timed out at epoch {epoch}"));
            }
            match self.pump(POLL) {
                Err(e) => return fatal(e),
                Ok(None) => self.check_stale(),
                Ok(Some((rank, Ctrl::CkptAck { rank: r2, epoch: e }))) => {
                    if rank == r2 && e == epoch {
                        acked[rank] = true;
                        self.worker_last_ckpt[rank] = Some(epoch);
                    }
                }
                Ok(Some(_)) => {}
            }
        }
        self.last_shards = Some(shards);
        Ok(())
    }

    fn ckpt_due(&self, epoch: usize) -> bool {
        self.cfg.ckpt_every > 0
            && ((epoch + 1) % self.cfg.ckpt_every == 0 || epoch + 1 == self.cfg.epochs)
    }

    fn spawn_worker(&mut self, rank: usize, clear_crash: bool) -> Result<()> {
        let Some((exe, cfg_path)) = &self.spawn_cmd else {
            return Ok(()); // external supervisor owns the processes
        };
        let mut cmd = Command::new(exe);
        cmd.arg("worker")
            .arg("--config")
            .arg(cfg_path)
            .arg("--rank")
            .arg(rank.to_string());
        if clear_crash {
            // a respawned worker must not re-trip the injected crash
            cmd.arg("--crash_at=");
        }
        let child = cmd
            .spawn()
            .map_err(|e| anyhow::anyhow!("cannot spawn worker {rank} ({exe:?}): {e}"))?;
        if let Some(mut old) = self.children[rank].take() {
            let _ = old.kill();
            let _ = old.wait();
        }
        self.children[rank] = Some(child);
        Ok(())
    }

    /// Full crash recovery.  `epoch_in_progress` is the epoch that was
    /// running (or about to run) when the death was detected; returns the
    /// epoch to resume from.
    fn recover(&mut self, epoch_in_progress: usize) -> Result<usize> {
        loop {
            // a rank is part of this recovery round if its connection is
            // gone OR it already rejoined with a fresh, unwelcomed one
            let dead: Vec<usize> = (0..self.q())
                .filter(|&r| self.slots[r].is_none() || self.needs_welcome[r])
                .collect();
            anyhow::ensure!(!dead.is_empty(), "recover invoked with every worker alive");
            self.restarts += dead.len();
            // ROADMAP item 1: a dead rank takes its stale-replay payload
            // cache (and, under staleness > 0, its historical-embedding
            // cache) with it; the rewind directive makes every survivor
            // reset too, so replayed epochs are fleet-wide consistent.
            // Surface the cause so operators can see replay-affecting
            // resets in the report.
            if self.cfg.stale_prob > 0.0 || self.cfg.staleness > 0 {
                self.stale_cache_resets += dead.len();
            }
            anyhow::ensure!(
                self.restarts <= self.cfg.max_restarts,
                "worker(s) {dead:?} died at epoch {epoch_in_progress} and the restart budget \
                 (max_restarts = {}) is exhausted",
                self.cfg.max_restarts
            );
            eprintln!(
                "[varco driver] worker(s) {dead:?} lost at epoch {epoch_in_progress}; \
                 recovering (restarts {}/{})",
                self.restarts, self.cfg.max_restarts
            );
            // pause survivors: abort wakes any blocked halo receive.
            // Freshly rejoined ranks are skipped — they have nothing in
            // flight and an abort would poison their reset data plane.
            for r in 0..self.q() {
                if !self.needs_welcome[r] {
                    self.send_to(r, &Ctrl::Abort);
                }
            }
            for &r in &dead {
                if self.slots[r].is_none() {
                    self.spawn_worker(r, true)?;
                }
            }
            let resume = match &self.last_shards {
                Some(shards) => {
                    let ss = ShardSet::from_shards(shards.clone())?;
                    anyhow::ensure!(
                        ss.checkpoint.model == self.ctx.spec.name
                            && ss.checkpoint.seed == self.cfg.seed,
                        "retained shard set does not match this run"
                    );
                    self.weights = ss.checkpoint.to_weights()?;
                    self.optimizer = crate::optim::by_name(
                        &self.cfg.optimizer,
                        self.cfg.lr,
                        self.cfg.weight_decay,
                    )?;
                    self.optimizer.restore(&ss.optimizer)?;
                    // rewind the controller to the checkpointed plan so
                    // replayed epochs are observed exactly once
                    self.controller = build_controller(self.cfg)?;
                    if let Some(blob) = ss.residuals.first() {
                        self.controller.restore(blob)?;
                    }
                    ss.checkpoint.epoch + 1
                }
                None => {
                    // no checkpoint yet: restart training from scratch,
                    // controller included
                    self.weights = Weights::glorot(&self.ctx.spec, self.cfg.seed);
                    self.optimizer = crate::optim::by_name(
                        &self.cfg.optimizer,
                        self.cfg.lr,
                        self.cfg.weight_decay,
                    )?;
                    self.controller = build_controller(self.cfg)?;
                    0
                }
            };
            self.report.records.truncate(resume);
            self.stale_by_epoch.truncate(resume);
            self.links_by_epoch.truncate(resume);
            self.hist_by_epoch.truncate(resume);
            self.bytes_cum = self.report.records.last().map(|r| r.bytes_cum).unwrap_or(0);
            match self.admission_barrier(resume, true) {
                Ok(()) => {
                    // counted only once recovery succeeds, so a second
                    // death mid-barrier doesn't double-bill the replay
                    self.recovered_epochs += epoch_in_progress - resume;
                    eprintln!("[varco driver] recovered; replaying from epoch {resume}");
                    return Ok(resume);
                }
                Err(Interrupt::Dead) => continue, // another death mid-recovery
                Err(Interrupt::Fatal(e)) => return Err(e),
            }
        }
    }

    fn shutdown(&mut self) {
        self.broadcast(&Ctrl::Shutdown);
        // unblock and retire the accept loop
        self.closing.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect_timeout(&self.ctrl_addr, Duration::from_millis(250));
        // reap children: give them a moment to exit on their own
        let deadline = Instant::now() + Duration::from_secs(5);
        for r in 0..self.children.len() {
            if let Some(mut child) = self.children[r].take() {
                loop {
                    match child.try_wait() {
                        Ok(Some(_)) => break,
                        Ok(None) if Instant::now() > deadline => {
                            let _ = child.kill();
                            let _ = child.wait();
                            break;
                        }
                        Ok(None) => std::thread::sleep(Duration::from_millis(50)),
                        Err(_) => break,
                    }
                }
            }
        }
    }
}

/// Run the driver to completion.  Blocks until the configured number of
/// epochs has been trained (surviving up to `max_restarts` worker
/// deaths) and every worker has been told to shut down.
pub fn run_driver(cfg: &TrainConfig, opts: DriverOptions) -> Result<DistRun> {
    anyhow::ensure!(
        cfg.transport == "tcp",
        "run_driver requires transport=tcp (got {:?})",
        cfg.transport
    );
    let ctx = DistContext::build(cfg)?;
    let listener = match opts.listener {
        Some(l) => l,
        None => TcpListener::bind(&cfg.driver_addr)
            .map_err(|e| anyhow::anyhow!("driver cannot bind {:?}: {e}", cfg.driver_addr))?,
    };
    let ctrl_addr = listener.local_addr()?;

    // accept thread: one monitor thread per control connection
    let (accept_tx, rx) = channel::<Event>();
    let closing = Arc::new(AtomicBool::new(false));
    let accept_closing = Arc::clone(&closing);
    std::thread::Builder::new()
        .name("varco-driver-accept".into())
        .spawn(move || {
            let mut next_conn: u64 = 0;
            for conn in listener.incoming() {
                if accept_closing.load(Ordering::SeqCst) {
                    break; // shutdown self-connect woke us
                }
                let Ok(stream) = conn else { break };
                let id = next_conn;
                next_conn += 1;
                let mtx = accept_tx.clone();
                let _ = std::thread::Builder::new()
                    .name(format!("varco-driver-monitor-{id}"))
                    .spawn(move || monitor(stream, id, mtx));
            }
        })
        .map_err(|e| anyhow::anyhow!("cannot spawn accept thread: {e}"))?;

    let q = ctx.q;
    let layer_dims = ctx.spec.layer_dims();
    let eval = FullGraphEval::from_store(ctx.store.clone(), &ctx.spec)?;
    let controller = build_controller(cfg)?;
    let shards = ctx.store.shard_summary();
    let report = RunReport {
        algorithm: controller.label(),
        dataset: ctx.store.name().to_string(),
        partitioner: cfg.partitioner.clone(),
        q,
        seed: cfg.seed,
        engine: "native".into(),
        model: ctx.spec.name.clone(),
        store: ctx.store.backend().to_string(),
        store_shards: shards.as_ref().map(|s| s.shards).unwrap_or(0),
        store_mapped_bytes: shards.as_ref().map(|s| s.mapped_bytes).unwrap_or(0),
        records: Vec::new(),
        stale_skipped: 0,
        // filled at the end of the run from the per-epoch link cells the
        // workers ship in their outcomes (halo traffic; the constant
        // weight-sync charge has no (sender, receiver) link)
        link_bytes: Vec::new(),
        ..Default::default()
    };
    let mut driver = Driver {
        cfg,
        hash: admission_hash(cfg)?,
        layer_dims,
        rx,
        slots: (0..q).map(|_| None).collect(),
        needs_welcome: vec![false; q],
        last_seen: vec![Instant::now(); q],
        eval,
        weights: Weights::glorot(&ctx.spec, cfg.seed),
        optimizer: crate::optim::by_name(&cfg.optimizer, cfg.lr, cfg.weight_decay)?,
        controller,
        report,
        bytes_cum: 0,
        stale_by_epoch: Vec::new(),
        links_by_epoch: Vec::new(),
        hist_by_epoch: Vec::new(),
        sampling: cfg.sampling_config()?,
        stale_cache_resets: 0,
        last_links: None,
        restarts: 0,
        recovered_epochs: 0,
        heartbeat_timeouts: 0,
        worker_last_ckpt: vec![None; q],
        last_shards: None,
        children: (0..q).map(|_| None).collect(),
        spawn_cmd: None,
        ctrl_addr,
        closing,
        ctx,
    };

    // whole-cluster restart: adopt the on-disk shard set as both the
    // starting state and the recovery point
    let mut start_epoch = 0;
    if opts.resume {
        let dir = std::path::Path::new(&cfg.ckpt_dir);
        let ss = ShardSet::load(dir, "dist")
            .map_err(|e| anyhow::anyhow!("--resume: cannot load shard set from {dir:?}: {e}"))?;
        anyhow::ensure!(
            ss.checkpoint.model == driver.ctx.spec.name && ss.checkpoint.seed == cfg.seed,
            "--resume: shard set in {dir:?} is from a different run \
             (model {} seed {}, config says {} / {})",
            ss.checkpoint.model,
            ss.checkpoint.seed,
            driver.ctx.spec.name,
            cfg.seed
        );
        start_epoch = ss.checkpoint.epoch + 1;
        driver.weights = ss.checkpoint.to_weights()?;
        driver.optimizer.restore(&ss.optimizer)?;
        // legacy shard sets carry no controller snapshot; skip the empty
        // blob so stateful controllers fall back to their fresh plan
        if let Some(blob) = ss.residuals.first().filter(|b| !b.is_empty()) {
            driver.controller.restore(blob)?;
        }
        driver.last_shards = Some(ShardSet::make_shards(
            &driver.ctx.spec,
            &ss.checkpoint.flat_weights,
            &ss.optimizer,
            &ss.residuals,
            ss.checkpoint.epoch,
            cfg.seed,
            q,
        ));
        eprintln!("[varco driver] resuming from epoch {start_epoch} ({dir:?})");
    }

    if opts.spawn_workers {
        // persist the resolved config (with the actual bound address) so
        // children — and any respawn — see exactly this run
        let dir = std::path::Path::new(&cfg.ckpt_dir);
        std::fs::create_dir_all(dir)?;
        let cfg_path = dir.join("resolved.cfg");
        let mut resolved = cfg.clone();
        resolved.driver_addr = ctrl_addr.to_string();
        std::fs::write(&cfg_path, resolved.to_config_string())?;
        let exe = std::env::current_exe()
            .map_err(|e| anyhow::anyhow!("cannot locate the varco binary: {e}"))?;
        driver.spawn_cmd = Some((exe, cfg_path));
        for r in 0..q {
            driver.spawn_worker(r, false)?;
        }
    }

    eprintln!(
        "[varco driver] control plane on {ctrl_addr}; waiting for {q} worker(s) \
         [{}]",
        driver.cfg.describe()
    );
    match driver.admission_barrier(start_epoch, false) {
        Ok(()) => {}
        Err(Interrupt::Dead) => {
            // a worker died before the first plan; recovery re-runs the barrier
            start_epoch = match driver.recover(start_epoch) {
                Ok(e) => e,
                Err(e) => {
                    driver.shutdown();
                    return Err(e);
                }
            };
        }
        Err(Interrupt::Fatal(e)) => {
            driver.shutdown();
            return Err(e);
        }
    }

    let mut epoch = start_epoch;
    while epoch < cfg.epochs {
        let step = driver.run_epoch(epoch).and_then(|()| {
            if driver.ckpt_due(epoch) {
                driver.checkpoint(epoch)
            } else {
                Ok(())
            }
        });
        match step {
            Ok(()) => epoch += 1,
            Err(Interrupt::Dead) => match driver.recover(epoch) {
                Ok(resume) => epoch = resume,
                Err(e) => {
                    driver.shutdown();
                    return Err(e);
                }
            },
            Err(Interrupt::Fatal(e)) => {
                driver.shutdown();
                return Err(e);
            }
        }
    }

    driver.shutdown();
    driver.report.stale_skipped = driver.stale_by_epoch.iter().sum::<u64>() as usize;
    if driver.sampling.is_some() {
        // one deterministic batch per epoch, mirroring the in-process path
        driver.report.batches = cfg.epochs;
    }
    let mut age_hist: Vec<usize> = Vec::new();
    for h in &driver.hist_by_epoch {
        driver.report.hist_hits += h.hits as usize;
        driver.report.hist_misses += h.misses as usize;
        driver.report.hist_refresh_rows += h.refresh_rows as usize;
        if h.ages.len() > age_hist.len() {
            age_hist.resize(h.ages.len(), 0);
        }
        for (slot, &a) in age_hist.iter_mut().zip(&h.ages) {
            *slot += a as usize;
        }
    }
    driver.report.hist_age_hist = age_hist;
    driver.report.stale_cache_resets = driver.stale_cache_resets;
    let mut link_sum: BTreeMap<(usize, usize), (usize, usize)> = BTreeMap::new();
    for cells in &driver.links_by_epoch {
        for c in cells {
            let e = link_sum.entry((c.from, c.to)).or_insert((0, 0));
            e.0 += c.bytes;
            e.1 += c.msgs;
        }
    }
    driver.report.link_bytes = link_sum
        .into_iter()
        .map(|((from, to), (bytes, messages))| LinkTraffic { from, to, bytes, messages })
        .collect();
    if let Some(lr) = &driver.last_links {
        driver.report.link_rates = lr.to_report();
    }
    driver.report.restarts = driver.restarts;
    driver.report.recovered_epochs = driver.recovered_epochs;
    driver.report.heartbeat_timeouts = driver.heartbeat_timeouts;
    driver.report.worker_last_ckpt = driver.worker_last_ckpt.clone();
    Ok(DistRun { report: driver.report, weights: driver.weights })
}
