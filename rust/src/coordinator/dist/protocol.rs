//! The driver <-> worker control protocol.
//!
//! Control messages ride the same length-prefixed frame codec as the data
//! plane (`comm::transport::frame`, tag [`TAG_CTRL`]) over a dedicated
//! TCP connection per worker.  The protocol is deliberately thin: because
//! every process deterministically rebuilds the full run setup from the
//! shared config (see `coordinator::trainer::RunSetup`), only mutable
//! training state crosses the wire — flat weights out in each [`Ctrl::Plan`],
//! flat gradient sums back in each [`Ctrl::Outcome`], checkpoint shard bytes
//! in [`Ctrl::Checkpoint`].
//!
//! Lifecycle: a worker connects and sends [`Ctrl::Join`]; the driver
//! answers [`Ctrl::Welcome`] with the data-plane peer addresses; the
//! worker wires its [`TcpTransport`](crate::comm::TcpTransport) links and
//! confirms [`Ctrl::Ready`].  Per epoch the driver broadcasts a `Plan` and
//! collects one `Outcome` per rank.  On a worker death the driver
//! broadcasts [`Ctrl::Abort`] (waking survivors out of any blocked
//! receive), re-admits the restarted rank, and sends survivors
//! [`Ctrl::Rewind`] with the changed peer addresses.  [`Ctrl::Heartbeat`]
//! flows worker->driver on a fixed cadence so hangs (not just socket
//! deaths) are detected.
//!
//! Encoding is hand-rolled little-endian (no serde in the dependency
//! footprint), with explicit caps on every length field so a corrupt or
//! hostile peer produces a clear error instead of an allocation blow-up.

use crate::comm::transport::frame::{read_frame, write_frame, TAG_CTRL};
use crate::compress::{LayerFeedback, LinkCell};
use crate::Result;
use std::io::{Read, Write};

/// Longest admissible string field (addresses, error messages).
const MAX_STR: u64 = 1 << 16;
/// Longest admissible f32 vector (weights/gradients; 1<<28 floats = 1 GiB).
const MAX_F32S: u64 = 1 << 28;
/// Most peers / layers a message may carry.
const MAX_ITEMS: u64 = 1 << 20;

/// One control-plane message.  See the module docs for the lifecycle.
#[derive(Clone, Debug, PartialEq)]
pub enum Ctrl {
    /// worker -> driver: first message on the control connection
    Join {
        rank: usize,
        /// advertised data-plane listen address of this worker
        data_addr: String,
        /// FNV hash of the training-semantic config; the driver refuses
        /// ranks whose view of the run disagrees with its own
        config_hash: u64,
    },
    /// driver -> worker: admission + full data-plane peer directory
    Welcome {
        /// first epoch the worker will be asked to run (0 on a fresh
        /// start, the replay point after a recovery)
        resume_epoch: usize,
        /// (rank, data_addr) for every rank, self included
        peers: Vec<(usize, String)>,
    },
    /// worker -> driver: data-plane links are wired, ready for plans
    Ready { rank: usize },
    /// driver -> worker: one epoch of work (weights travel with the plan,
    /// which is what makes workers stateless across epochs — and is the
    /// entire recovery story: re-admitted ranks need no state transfer)
    Plan {
        epoch: usize,
        fwd: Vec<Option<f32>>,
        bwd: Vec<Option<f32>>,
        nominal: Option<f32>,
        feedback: bool,
        local_norm: bool,
        /// flat per-(layer, sender, receiver) rate matrix from a
        /// link-aware controller (`layers * q * q`, <= 0 = no override);
        /// empty for uniform-rate plans
        links: Vec<f32>,
        weights: Vec<f32>,
    },
    /// worker -> driver: the epoch's result (or a compute error)
    Outcome {
        rank: usize,
        epoch: usize,
        loss_weighted: f32,
        /// flat parameter-gradient contribution (empty when `error`)
        grads: Vec<f32>,
        /// per-layer wire/error measurements for the rate controller
        feedback: Vec<LayerFeedback>,
        /// fabric byte-counter delta over this epoch
        bytes: u64,
        /// stale-injection skip-counter delta over this epoch
        stale_skipped: u64,
        /// historical-cache hit/miss/refresh-row deltas over this epoch
        /// (all zero unless the run has staleness > 0)
        hist_hits: u64,
        hist_misses: u64,
        hist_refresh_rows: u64,
        /// staleness-age histogram delta (slot 0 = refreshed rows, slot a
        /// = rows served at age a); empty for staleness = 0 runs
        hist_ages: Vec<u64>,
        /// per-link ledger-breakdown delta over this epoch (this rank's
        /// halo sends; the driver merges ranks in order)
        links: Vec<LinkCell>,
        error: Option<String>,
    },
    /// worker -> driver: liveness beacon on a fixed cadence
    Heartbeat { rank: usize },
    /// driver -> worker: persist this rank's checkpoint shard
    Checkpoint { epoch: usize, shard: Vec<u8> },
    /// worker -> driver: shard durably written
    CkptAck { rank: usize, epoch: usize },
    /// driver -> survivor: a rank was restarted; reset the data plane,
    /// reconnect the listed (changed) peers, and await replayed plans
    Rewind { resume_epoch: usize, peers: Vec<(usize, String)> },
    /// worker -> driver: rewind applied, links rewired
    RewindAck { rank: usize },
    /// driver -> worker: abandon the in-flight epoch (wakes any blocked
    /// data-plane receive via `TcpTransport::abort`)
    Abort,
    /// driver -> worker: run complete, exit cleanly
    Shutdown,
}

// ---- primitive writers -------------------------------------------------

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u64(buf, s.len() as u64);
    buf.extend_from_slice(s.as_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    put_u64(buf, xs.len() as u64);
    for &x in xs {
        put_f32(buf, x);
    }
}

fn put_opt_f32(buf: &mut Vec<u8>, v: Option<f32>) {
    match v {
        Some(x) => {
            buf.push(1);
            put_f32(buf, x);
        }
        None => buf.push(0),
    }
}

fn put_rates(buf: &mut Vec<u8>, rates: &[Option<f32>]) {
    put_u64(buf, rates.len() as u64);
    for &r in rates {
        put_opt_f32(buf, r);
    }
}

fn put_bytes(buf: &mut Vec<u8>, b: &[u8]) {
    put_u64(buf, b.len() as u64);
    buf.extend_from_slice(b);
}

// ---- primitive readers -------------------------------------------------

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        anyhow::ensure!(
            self.buf.len() - self.pos >= n,
            "ctrl decode: truncated {what} (need {n} bytes at offset {}, have {})",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let s = self.take(8, what)?;
        Ok(u64::from_le_bytes(s.try_into().unwrap()))
    }

    fn usize_capped(&mut self, cap: u64, what: &str) -> Result<usize> {
        let v = self.u64(what)?;
        anyhow::ensure!(v <= cap, "ctrl decode: {what} length {v} exceeds cap {cap}");
        Ok(v as usize)
    }

    fn f32(&mut self, what: &str) -> Result<f32> {
        let s = self.take(4, what)?;
        Ok(f32::from_le_bytes(s.try_into().unwrap()))
    }

    fn str_(&mut self, what: &str) -> Result<String> {
        let n = self.usize_capped(MAX_STR, what)?;
        let s = self.take(n, what)?;
        String::from_utf8(s.to_vec())
            .map_err(|_| anyhow::anyhow!("ctrl decode: {what} is not valid utf-8"))
    }

    fn f32s(&mut self, what: &str) -> Result<Vec<f32>> {
        let n = self.usize_capped(MAX_F32S, what)?;
        let s = self.take(n * 4, what)?;
        Ok(s.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn opt_f32(&mut self, what: &str) -> Result<Option<f32>> {
        match self.u8(what)? {
            0 => Ok(None),
            1 => Ok(Some(self.f32(what)?)),
            t => anyhow::bail!("ctrl decode: bad option tag {t} in {what}"),
        }
    }

    fn rates(&mut self, what: &str) -> Result<Vec<Option<f32>>> {
        let n = self.usize_capped(MAX_ITEMS, what)?;
        (0..n).map(|_| self.opt_f32(what)).collect()
    }

    fn bytes(&mut self, what: &str) -> Result<Vec<u8>> {
        let n = self.usize_capped(MAX_F32S * 4, what)?;
        Ok(self.take(n, what)?.to_vec())
    }

    fn done(&self, what: &str) -> Result<()> {
        anyhow::ensure!(
            self.pos == self.buf.len(),
            "ctrl decode: {} trailing bytes after {what}",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

// ---- message codec -----------------------------------------------------

const T_JOIN: u8 = 1;
const T_WELCOME: u8 = 2;
const T_READY: u8 = 3;
const T_PLAN: u8 = 4;
const T_OUTCOME: u8 = 5;
const T_HEARTBEAT: u8 = 6;
const T_CHECKPOINT: u8 = 7;
const T_CKPT_ACK: u8 = 8;
const T_REWIND: u8 = 9;
const T_REWIND_ACK: u8 = 10;
const T_ABORT: u8 = 11;
const T_SHUTDOWN: u8 = 12;

fn put_peers(buf: &mut Vec<u8>, peers: &[(usize, String)]) {
    put_u64(buf, peers.len() as u64);
    for (rank, addr) in peers {
        put_u64(buf, *rank as u64);
        put_str(buf, addr);
    }
}

fn read_peers(c: &mut Cursor, what: &str) -> Result<Vec<(usize, String)>> {
    let n = c.usize_capped(MAX_ITEMS, what)?;
    (0..n).map(|_| Ok((c.u64(what)? as usize, c.str_(what)?))).collect()
}

pub fn encode_ctrl(msg: &Ctrl) -> Vec<u8> {
    let mut b = Vec::new();
    match msg {
        Ctrl::Join { rank, data_addr, config_hash } => {
            b.push(T_JOIN);
            put_u64(&mut b, *rank as u64);
            put_str(&mut b, data_addr);
            put_u64(&mut b, *config_hash);
        }
        Ctrl::Welcome { resume_epoch, peers } => {
            b.push(T_WELCOME);
            put_u64(&mut b, *resume_epoch as u64);
            put_peers(&mut b, peers);
        }
        Ctrl::Ready { rank } => {
            b.push(T_READY);
            put_u64(&mut b, *rank as u64);
        }
        Ctrl::Plan { epoch, fwd, bwd, nominal, feedback, local_norm, links, weights } => {
            b.push(T_PLAN);
            put_u64(&mut b, *epoch as u64);
            put_rates(&mut b, fwd);
            put_rates(&mut b, bwd);
            put_opt_f32(&mut b, *nominal);
            b.push(u8::from(*feedback));
            b.push(u8::from(*local_norm));
            put_f32s(&mut b, links);
            put_f32s(&mut b, weights);
        }
        Ctrl::Outcome {
            rank,
            epoch,
            loss_weighted,
            grads,
            feedback,
            bytes,
            stale_skipped,
            hist_hits,
            hist_misses,
            hist_refresh_rows,
            hist_ages,
            links,
            error,
        } => {
            b.push(T_OUTCOME);
            put_u64(&mut b, *rank as u64);
            put_u64(&mut b, *epoch as u64);
            put_f32(&mut b, *loss_weighted);
            put_f32s(&mut b, grads);
            put_u64(&mut b, feedback.len() as u64);
            for f in feedback {
                put_u64(&mut b, f.bytes as u64);
                put_f32(&mut b, f.err_sq);
                put_f32(&mut b, f.sig_sq);
            }
            put_u64(&mut b, *bytes);
            put_u64(&mut b, *stale_skipped);
            put_u64(&mut b, *hist_hits);
            put_u64(&mut b, *hist_misses);
            put_u64(&mut b, *hist_refresh_rows);
            put_u64(&mut b, hist_ages.len() as u64);
            for &a in hist_ages {
                put_u64(&mut b, a);
            }
            put_u64(&mut b, links.len() as u64);
            for l in links {
                put_u64(&mut b, l.from as u64);
                put_u64(&mut b, l.to as u64);
                put_u64(&mut b, l.bytes as u64);
                put_u64(&mut b, l.msgs as u64);
            }
            match error {
                Some(e) => {
                    b.push(1);
                    put_str(&mut b, e);
                }
                None => b.push(0),
            }
        }
        Ctrl::Heartbeat { rank } => {
            b.push(T_HEARTBEAT);
            put_u64(&mut b, *rank as u64);
        }
        Ctrl::Checkpoint { epoch, shard } => {
            b.push(T_CHECKPOINT);
            put_u64(&mut b, *epoch as u64);
            put_bytes(&mut b, shard);
        }
        Ctrl::CkptAck { rank, epoch } => {
            b.push(T_CKPT_ACK);
            put_u64(&mut b, *rank as u64);
            put_u64(&mut b, *epoch as u64);
        }
        Ctrl::Rewind { resume_epoch, peers } => {
            b.push(T_REWIND);
            put_u64(&mut b, *resume_epoch as u64);
            put_peers(&mut b, peers);
        }
        Ctrl::RewindAck { rank } => {
            b.push(T_REWIND_ACK);
            put_u64(&mut b, *rank as u64);
        }
        Ctrl::Abort => b.push(T_ABORT),
        Ctrl::Shutdown => b.push(T_SHUTDOWN),
    }
    b
}

pub fn decode_ctrl(buf: &[u8]) -> Result<Ctrl> {
    let mut c = Cursor::new(buf);
    let tag = c.u8("ctrl tag")?;
    let msg = match tag {
        T_JOIN => Ctrl::Join {
            rank: c.u64("join.rank")? as usize,
            data_addr: c.str_("join.data_addr")?,
            config_hash: c.u64("join.config_hash")?,
        },
        T_WELCOME => Ctrl::Welcome {
            resume_epoch: c.u64("welcome.resume_epoch")? as usize,
            peers: read_peers(&mut c, "welcome.peers")?,
        },
        T_READY => Ctrl::Ready { rank: c.u64("ready.rank")? as usize },
        T_PLAN => Ctrl::Plan {
            epoch: c.u64("plan.epoch")? as usize,
            fwd: c.rates("plan.fwd")?,
            bwd: c.rates("plan.bwd")?,
            nominal: c.opt_f32("plan.nominal")?,
            feedback: c.u8("plan.feedback")? != 0,
            local_norm: c.u8("plan.local_norm")? != 0,
            links: c.f32s("plan.links")?,
            weights: c.f32s("plan.weights")?,
        },
        T_OUTCOME => {
            let rank = c.u64("outcome.rank")? as usize;
            let epoch = c.u64("outcome.epoch")? as usize;
            let loss_weighted = c.f32("outcome.loss")?;
            let grads = c.f32s("outcome.grads")?;
            let nf = c.usize_capped(MAX_ITEMS, "outcome.feedback")?;
            let mut feedback = Vec::with_capacity(nf);
            for _ in 0..nf {
                feedback.push(LayerFeedback {
                    bytes: c.u64("outcome.feedback.bytes")? as usize,
                    err_sq: c.f32("outcome.feedback.err_sq")?,
                    sig_sq: c.f32("outcome.feedback.sig_sq")?,
                });
            }
            let bytes = c.u64("outcome.bytes")?;
            let stale_skipped = c.u64("outcome.stale_skipped")?;
            let hist_hits = c.u64("outcome.hist_hits")?;
            let hist_misses = c.u64("outcome.hist_misses")?;
            let hist_refresh_rows = c.u64("outcome.hist_refresh_rows")?;
            let na = c.usize_capped(MAX_ITEMS, "outcome.hist_ages")?;
            let mut hist_ages = Vec::with_capacity(na);
            for _ in 0..na {
                hist_ages.push(c.u64("outcome.hist_ages")?);
            }
            let nl = c.usize_capped(MAX_ITEMS, "outcome.links")?;
            let mut links = Vec::with_capacity(nl);
            for _ in 0..nl {
                links.push(LinkCell {
                    from: c.u64("outcome.links.from")? as usize,
                    to: c.u64("outcome.links.to")? as usize,
                    bytes: c.u64("outcome.links.bytes")? as usize,
                    msgs: c.u64("outcome.links.msgs")? as usize,
                });
            }
            let error = match c.u8("outcome.error")? {
                0 => None,
                1 => Some(c.str_("outcome.error")?),
                t => anyhow::bail!("ctrl decode: bad option tag {t} in outcome.error"),
            };
            Ctrl::Outcome {
                rank,
                epoch,
                loss_weighted,
                grads,
                feedback,
                bytes,
                stale_skipped,
                hist_hits,
                hist_misses,
                hist_refresh_rows,
                hist_ages,
                links,
                error,
            }
        }
        T_HEARTBEAT => Ctrl::Heartbeat { rank: c.u64("heartbeat.rank")? as usize },
        T_CHECKPOINT => Ctrl::Checkpoint {
            epoch: c.u64("checkpoint.epoch")? as usize,
            shard: c.bytes("checkpoint.shard")?,
        },
        T_CKPT_ACK => Ctrl::CkptAck {
            rank: c.u64("ckpt_ack.rank")? as usize,
            epoch: c.u64("ckpt_ack.epoch")? as usize,
        },
        T_REWIND => Ctrl::Rewind {
            resume_epoch: c.u64("rewind.resume_epoch")? as usize,
            peers: read_peers(&mut c, "rewind.peers")?,
        },
        T_REWIND_ACK => Ctrl::RewindAck { rank: c.u64("rewind_ack.rank")? as usize },
        T_ABORT => Ctrl::Abort,
        T_SHUTDOWN => Ctrl::Shutdown,
        t => anyhow::bail!("ctrl decode: unknown message tag {t}"),
    };
    c.done("ctrl message")?;
    Ok(msg)
}

/// Write one control message as a `TAG_CTRL` frame.
pub fn write_ctrl(w: &mut impl Write, msg: &Ctrl) -> std::io::Result<()> {
    write_frame(w, TAG_CTRL, &encode_ctrl(msg))
}

/// Read one control message.  `Ok(None)` means the peer closed the
/// connection cleanly between frames.
pub fn read_ctrl(r: &mut impl Read) -> Result<Option<Ctrl>> {
    match read_frame(r)? {
        None => Ok(None),
        Some((TAG_CTRL, body)) => Ok(Some(decode_ctrl(&body)?)),
        Some((tag, _)) => anyhow::bail!("unexpected frame tag {tag:#x} on control connection"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Ctrl) {
        let mut wire = Vec::new();
        write_ctrl(&mut wire, &msg).unwrap();
        let mut r = &wire[..];
        let got = read_ctrl(&mut r).unwrap().expect("one message");
        assert_eq!(got, msg);
        assert!(read_ctrl(&mut r).unwrap().is_none(), "clean EOF after message");
    }

    #[test]
    fn all_variants_roundtrip() {
        roundtrip(Ctrl::Join { rank: 3, data_addr: "127.0.0.1:4041".into(), config_hash: 0xfeed });
        roundtrip(Ctrl::Welcome {
            resume_epoch: 7,
            peers: vec![(0, "127.0.0.1:5000".into()), (1, "127.0.0.1:5001".into())],
        });
        roundtrip(Ctrl::Ready { rank: 1 });
        roundtrip(Ctrl::Plan {
            epoch: 12,
            fwd: vec![Some(0.25), None],
            bwd: vec![None, Some(1.0)],
            nominal: Some(0.5),
            feedback: true,
            local_norm: false,
            links: vec![0.0, 2.0, 4.0, 0.0, 0.0, 1.0, 8.0, 0.0],
            weights: vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE],
        });
        roundtrip(Ctrl::Outcome {
            rank: 0,
            epoch: 12,
            loss_weighted: 3.25,
            grads: vec![0.5; 9],
            feedback: vec![LayerFeedback { bytes: 40, err_sq: 0.125, sig_sq: 2.0 }],
            bytes: 1234,
            stale_skipped: 2,
            hist_hits: 17,
            hist_misses: 1,
            hist_refresh_rows: 9,
            hist_ages: vec![9, 10, 7],
            links: vec![LinkCell { from: 0, to: 1, bytes: 640, msgs: 4 }],
            error: None,
        });
        roundtrip(Ctrl::Outcome {
            rank: 1,
            epoch: 3,
            loss_weighted: 0.0,
            grads: vec![],
            feedback: vec![],
            bytes: 0,
            stale_skipped: 0,
            hist_hits: 0,
            hist_misses: 0,
            hist_refresh_rows: 0,
            hist_ages: vec![],
            links: vec![],
            error: Some("link to worker 0 is down".into()),
        });
        roundtrip(Ctrl::Heartbeat { rank: 2 });
        roundtrip(Ctrl::Checkpoint { epoch: 4, shard: vec![9, 8, 7, 6] });
        roundtrip(Ctrl::CkptAck { rank: 2, epoch: 4 });
        roundtrip(Ctrl::Rewind { resume_epoch: 2, peers: vec![(1, "127.0.0.1:6001".into())] });
        roundtrip(Ctrl::RewindAck { rank: 0 });
        roundtrip(Ctrl::Abort);
        roundtrip(Ctrl::Shutdown);
    }

    #[test]
    fn truncated_and_corrupt_messages_error_cleanly() {
        let body = encode_ctrl(&Ctrl::Plan {
            epoch: 1,
            fwd: vec![Some(0.5)],
            bwd: vec![Some(0.5)],
            nominal: Some(0.5),
            feedback: false,
            local_norm: false,
            links: vec![0.0, 2.0],
            weights: vec![1.0, 2.0],
        });
        for cut in 1..body.len() {
            assert!(decode_ctrl(&body[..cut]).is_err(), "truncation at {cut} must error");
        }
        let body = encode_ctrl(&Ctrl::Outcome {
            rank: 0,
            epoch: 1,
            loss_weighted: 1.0,
            grads: vec![0.5],
            feedback: vec![LayerFeedback { bytes: 8, err_sq: 0.5, sig_sq: 1.0 }],
            bytes: 8,
            stale_skipped: 0,
            hist_hits: 3,
            hist_misses: 0,
            hist_refresh_rows: 2,
            hist_ages: vec![2, 3],
            links: vec![LinkCell { from: 0, to: 1, bytes: 8, msgs: 1 }],
            error: None,
        });
        for cut in 1..body.len() {
            assert!(decode_ctrl(&body[..cut]).is_err(), "truncation at {cut} must error");
        }
        // unknown tag
        assert!(decode_ctrl(&[0xEE]).is_err());
        // trailing garbage
        let mut long = encode_ctrl(&Ctrl::Abort);
        long.push(0);
        assert!(decode_ctrl(&long).is_err());
        // absurd length field caps out instead of allocating
        let mut huge = vec![T_JOIN];
        huge.extend_from_slice(&0u64.to_le_bytes());
        huge.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_ctrl(&huge).is_err());
    }

    #[test]
    fn wrong_frame_tag_rejected() {
        let mut wire = Vec::new();
        crate::comm::transport::frame::write_frame(
            &mut wire,
            crate::comm::transport::frame::TAG_DATA,
            &[1, 2, 3],
        )
        .unwrap();
        assert!(read_ctrl(&mut &wire[..]).is_err());
    }
}
