//! The worker process: owns one rank of the data plane, executes the
//! epochs the driver plans, and carries no training state of its own —
//! every [`Ctrl::Plan`] arrives with the full weight vector, so a worker
//! that crashed and was restarted is indistinguishable from one that
//! never died once it has rejoined and reconnected its halo links.
//!
//! Threads: the main directive loop (this function), a control-channel
//! reader (turns frames into events; applies [`Ctrl::Abort`] to the data
//! plane *immediately* so an epoch blocked in `recv_expected` wakes up),
//! and a heartbeat ticker.  Control writes are mutex-serialized because
//! the heartbeat and the directive loop share the socket.

use super::protocol::{read_ctrl, write_ctrl, Ctrl};
use super::{admission_hash, tcp_options, DistContext};
use crate::comm::{Fabric, FailurePolicy, LedgerMode, TcpTransport, Transport};
use crate::config::TrainConfig;
use crate::coordinator::checkpoint::CheckpointShard;
use crate::coordinator::trainer::{dist_worker_epoch, link_delta, EpochPlan, LinkRates, RunSetup};
use crate::engine::native::NativeWorkerEngine;
use crate::engine::Weights;
use crate::partition::{HistCache, HistStats, HistTracker, PlanRows};
use crate::util::Workspace;
use crate::Result;
use std::net::TcpStream;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// What to do when this rank hits its injected crash point
/// (`crash_at = "epoch:rank"`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CrashBehavior {
    /// `std::process::exit(137)` — a real SIGKILL-grade death, used by the
    /// multi-process runtime
    Exit,
    /// return from `run_worker` — lets in-thread tests simulate the crash
    /// without taking the test process down
    Return,
}

pub struct WorkerOptions {
    pub crash: CrashBehavior,
}

impl Default for WorkerOptions {
    fn default() -> WorkerOptions {
        WorkerOptions { crash: CrashBehavior::Exit }
    }
}

enum WireEvent {
    Ctrl(Ctrl),
    /// driver connection reached EOF or errored
    Closed,
}

/// This rank's deterministic replica of the historical-embedding state.
/// Every worker evolves an identical [`HistTracker`] from the shared
/// config, so sender and receiver agree on each epoch's refresh schedule
/// without exchanging it; the cache holds only this rank's boundary rows.
/// Cleared on `Welcome`/`Rewind` (all ranks reset together, so replicas
/// stay consistent across a recovery — the first replayed epoch ships
/// full refreshes).
struct HistWorker {
    tracker: HistTracker,
    cache: HistCache,
    /// plan-row identities the tracker schedules over; static for full
    /// mode, rebuilt from each epoch's view under sampled mode
    plan_rows: Vec<Vec<Vec<PlanRows>>>,
}

/// Reader thread body: every control frame becomes an event; Abort is
/// *also* applied to the data plane here, before the directive loop sees
/// it, so a worker blocked mid-exchange errors out instead of waiting for
/// a dead peer until the read timeout.
fn reader(mut stream: TcpStream, transport: Arc<TcpTransport>, tx: Sender<WireEvent>) {
    loop {
        match read_ctrl(&mut stream) {
            Ok(Some(ctrl)) => {
                if matches!(ctrl, Ctrl::Abort) {
                    transport.abort();
                }
                if tx.send(WireEvent::Ctrl(ctrl)).is_err() {
                    return;
                }
            }
            Ok(None) | Err(_) => {
                let _ = tx.send(WireEvent::Closed);
                return;
            }
        }
    }
}

fn send_ctrl(writer: &Mutex<TcpStream>, msg: &Ctrl) -> Result<()> {
    let mut w = writer.lock().unwrap();
    write_ctrl(&mut *w, msg).map_err(|e| anyhow::anyhow!("control channel write failed: {e}"))
}

/// Run one worker rank to completion (driver-directed shutdown), to an
/// injected crash, or to an error.
pub fn run_worker(cfg: &TrainConfig, rank: usize, opts: WorkerOptions) -> Result<()> {
    anyhow::ensure!(
        cfg.transport == "tcp",
        "run_worker requires transport=tcp (got {:?})",
        cfg.transport
    );
    anyhow::ensure!(rank < cfg.q, "rank {rank} out of range for q = {}", cfg.q);
    let ctx = DistContext::build(cfg)?;
    let compressor = crate::compress::by_name(&cfg.compressor)?;
    let mut engine =
        NativeWorkerEngine::new(ctx.worker_graphs[rank].clone(), ctx.spec.clone());
    let layer_dims = ctx.spec.layer_dims();
    let crash_at = cfg.crash_at_spec()?;
    let sampling = cfg.sampling_config()?;
    let plan_mode = crate::partition::PlanMode::parse(&cfg.plan)?;
    let mut hist = (cfg.staleness > 0).then(|| HistWorker {
        tracker: HistTracker::new(cfg.staleness),
        cache: HistCache::new(),
        plan_rows: ctx.setup.hist_plan_rows(&ctx.worker_graphs, |gid| gid),
    });

    // data plane: bind an ephemeral port; the driver's Welcome carries
    // everyone's advertised address
    let transport =
        Arc::new(TcpTransport::bind(rank, cfg.q, "127.0.0.1:0", tcp_options(cfg))?);
    let data_addr = transport.local_addr().to_string();
    let fabric = Fabric::with_transport(
        cfg.q,
        FailurePolicy { drop_prob: cfg.drop_prob, stale_prob: cfg.stale_prob, seed: cfg.seed },
        LedgerMode::Detailed,
        Arc::clone(&transport) as Arc<dyn Transport>,
    );
    let mut endpoint = fabric.endpoint(rank);
    let mut ws = Workspace::new();
    let mut weights = Weights::zeros(&ctx.spec);
    let param_count = weights.param_count();

    // control plane: dial the driver (retry inside the connect window —
    // workers often start before the driver's listener)
    let deadline = Instant::now() + Duration::from_millis(cfg.connect_timeout_ms.max(100));
    let ctrl = loop {
        match TcpStream::connect(&cfg.driver_addr) {
            Ok(s) => break s,
            Err(e) => {
                anyhow::ensure!(
                    Instant::now() < deadline,
                    "worker {rank} cannot reach driver at {:?}: {e}",
                    cfg.driver_addr
                );
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    };
    ctrl.set_nodelay(true).ok();
    let reader_stream = ctrl.try_clone()?;
    let writer = Arc::new(Mutex::new(ctrl));
    send_ctrl(
        &writer,
        &Ctrl::Join { rank, data_addr, config_hash: admission_hash(cfg)? },
    )?;

    let (tx, rx) = channel::<WireEvent>();
    let reader_transport = Arc::clone(&transport);
    std::thread::Builder::new()
        .name(format!("varco-worker{rank}-ctrl"))
        .spawn(move || reader(reader_stream, reader_transport, tx))
        .map_err(|e| anyhow::anyhow!("cannot spawn control reader: {e}"))?;

    let hb_stop = Arc::new(AtomicBool::new(false));
    let hb_writer = Arc::clone(&writer);
    let hb_flag = Arc::clone(&hb_stop);
    let hb_period = Duration::from_millis(cfg.heartbeat_ms.max(10));
    std::thread::Builder::new()
        .name(format!("varco-worker{rank}-hb"))
        .spawn(move || {
            while !hb_flag.load(Ordering::SeqCst) {
                std::thread::sleep(hb_period);
                if send_ctrl(&hb_writer, &Ctrl::Heartbeat { rank }).is_err() {
                    return; // driver gone; reader thread reports Closed
                }
            }
        })
        .map_err(|e| anyhow::anyhow!("cannot spawn heartbeat thread: {e}"))?;
    // make sure the ticker dies with us on every exit path
    struct StopOnDrop(Arc<AtomicBool>);
    impl Drop for StopOnDrop {
        fn drop(&mut self) {
            self.0.store(true, Ordering::SeqCst);
        }
    }
    let _hb_guard = StopOnDrop(Arc::clone(&hb_stop));

    // ---- directive loop ----
    loop {
        let ev = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker {rank}: control reader thread died"))?;
        let ctrl = match ev {
            WireEvent::Ctrl(c) => c,
            WireEvent::Closed => {
                anyhow::bail!("worker {rank}: lost connection to driver");
            }
        };
        match ctrl {
            Ctrl::Welcome { peers, .. } => {
                // a stray Abort can precede the Welcome when this worker
                // rejoined while the driver was still pausing survivors;
                // start from a clean plane either way
                transport.reset();
                // every rank resets its hist replica at every (re)admission,
                // so the refresh schedule stays consistent fleet-wide: the
                // first (re)played epoch ships full refreshes everywhere
                if let Some(h) = hist.as_mut() {
                    h.tracker.clear();
                    h.cache.clear();
                }
                transport.connect_peers(&peers)?;
                send_ctrl(&writer, &Ctrl::Ready { rank })?;
            }
            Ctrl::Rewind { peers, .. } => {
                // recovery: forget the aborted epoch's queue and re-dial
                // only the replaced ranks (survivor links are intact)
                transport.reset();
                if let Some(h) = hist.as_mut() {
                    h.tracker.clear();
                    h.cache.clear();
                }
                for (p, addr) in &peers {
                    if *p != rank {
                        transport.disconnect_peer(*p);
                        transport.connect_peer(*p, addr)?;
                    }
                }
                send_ctrl(&writer, &Ctrl::RewindAck { rank })?;
            }
            Ctrl::Plan { epoch, fwd, bwd, nominal, feedback, local_norm, links, weights: flat } => {
                if crash_at == Some((epoch, rank)) {
                    eprintln!("[varco worker {rank}] injected crash at epoch {epoch}");
                    match opts.crash {
                        CrashBehavior::Exit => std::process::exit(137),
                        CrashBehavior::Return => return Ok(()),
                    }
                }
                anyhow::ensure!(
                    flat.len() == param_count,
                    "plan for epoch {epoch} carries {} weights, model has {param_count}",
                    flat.len()
                );
                weights.set_from_flat(&flat);
                let links = (!links.is_empty())
                    .then(|| LinkRates { q: cfg.q, rates: links });
                // sampled mode: materialize this epoch's induced view — a
                // pure function of (config, seed, epoch), so every rank
                // (and any replay) rebuilds the same batch independently
                let view_setup;
                let setup = match &sampling {
                    Some(sc) => {
                        let view = crate::runtime::minibatch::build_view(
                            ctx.store.as_ref(),
                            &ctx.partition.assignment,
                            cfg.q,
                            sc,
                            cfg.seed,
                            epoch,
                        )?;
                        let s = RunSetup::build(
                            &view.dataset,
                            &view.worker_graphs,
                            &ctx.spec,
                            plan_mode,
                            cfg.replication,
                        )?;
                        if let Some(h) = hist.as_mut() {
                            // cache lines key by full-graph node id, so a
                            // boundary node keeps its history across batches
                            h.plan_rows = s.hist_plan_rows(&view.worker_graphs, |local| {
                                view.nodes[local as usize]
                            });
                        }
                        engine =
                            NativeWorkerEngine::new(view.worker_graphs[rank].clone(), ctx.spec.clone());
                        view_setup = s;
                        &view_setup
                    }
                    None => &ctx.setup,
                };
                let mut plan =
                    EpochPlan { fwd, bwd, local_norm, nominal, feedback, links, hist: None };
                if let Some(h) = hist.as_mut() {
                    plan.hist = Some(Arc::new(h.tracker.schedule(epoch, &h.plan_rows)));
                }
                let bytes0 = fabric.total_bytes();
                let stale0 = fabric.stale_skipped();
                let hist0 = hist.as_ref().map(|h| h.cache.stats.clone());
                // per-link baseline at plan receipt, so an aborted partial
                // epoch cannot inflate the replayed epoch's delta
                let mut links0 =
                    fabric.merged_ledger().breakdown_by_link_excluding("weights");
                let result = dist_worker_epoch(
                    epoch,
                    setup,
                    rank,
                    compressor.as_ref(),
                    cfg.seed,
                    &mut engine,
                    &mut endpoint,
                    &mut ws,
                    &weights,
                    &plan,
                    &layer_dims,
                    hist.as_mut().map(|h| &mut h.cache),
                );
                match result {
                    Ok(out) => {
                        let flat_g = Weights { layers: out.grads, version: 0 }.flatten();
                        let hs = match (&hist, &hist0) {
                            (Some(h), Some(b)) => h.cache.stats.since(b),
                            _ => HistStats::default(),
                        };
                        send_ctrl(
                            &writer,
                            &Ctrl::Outcome {
                                rank,
                                epoch,
                                loss_weighted: out.loss_weighted,
                                grads: flat_g,
                                feedback: out.feedback,
                                bytes: (fabric.total_bytes() - bytes0) as u64,
                                stale_skipped: (fabric.stale_skipped() - stale0) as u64,
                                hist_hits: hs.hits as u64,
                                hist_misses: hs.misses as u64,
                                hist_refresh_rows: hs.refresh_rows as u64,
                                hist_ages: hs.ages.iter().map(|&a| a as u64).collect(),
                                links: link_delta(&fabric.merged_ledger(), &mut links0),
                                error: None,
                            },
                        )?;
                    }
                    Err(_) if transport.is_aborted() => {
                        // driver-directed abort: recovery is under way; the
                        // Rewind directive will arrive next
                    }
                    Err(e) => {
                        send_ctrl(
                            &writer,
                            &Ctrl::Outcome {
                                rank,
                                epoch,
                                loss_weighted: 0.0,
                                grads: Vec::new(),
                                feedback: Vec::new(),
                                bytes: 0,
                                stale_skipped: 0,
                                hist_hits: 0,
                                hist_misses: 0,
                                hist_refresh_rows: 0,
                                hist_ages: Vec::new(),
                                links: Vec::new(),
                                error: Some(e.to_string()),
                            },
                        )?;
                    }
                }
            }
            Ctrl::Checkpoint { epoch, shard } => {
                let shard = CheckpointShard::from_bytes(&shard)?;
                anyhow::ensure!(
                    shard.rank == rank && shard.epoch == epoch,
                    "driver sent shard (rank {}, epoch {}) to worker {rank} at epoch {epoch}",
                    shard.rank,
                    shard.epoch
                );
                let dir = Path::new(&cfg.ckpt_dir);
                std::fs::create_dir_all(dir)?;
                shard.save(&CheckpointShard::path_for(dir, "dist", rank))?;
                send_ctrl(&writer, &Ctrl::CkptAck { rank, epoch })?;
            }
            Ctrl::Abort => {
                // the reader thread already flipped the transport flag;
                // nothing to do at this level
            }
            Ctrl::Shutdown => {
                eprintln!("[varco worker {rank}] shutdown");
                return Ok(());
            }
            other => {
                anyhow::bail!("worker {rank}: unexpected control message {other:?}");
            }
        }
    }
}
