//! Multi-process training runtime: a driver process plus one worker
//! process per rank, speaking the [`protocol`] control channel, with the
//! data plane (halo exchanges) carried by [`TcpTransport`] links between
//! workers.
//!
//! The design principle is **deterministic reconstruction**: every
//! process rebuilds the complete run setup — dataset, partition, send
//! plans, model spec — from the shared config via [`DistContext::build`],
//! so only mutable state crosses the wire.  The driver owns all of it
//! (weights, optimizer, rate controller, evaluation, the run report);
//! workers are stateless across epochs because each [`protocol::Ctrl::Plan`]
//! carries the full flat weight vector.  That statelessness is the whole
//! crash-recovery story: re-admitting a restarted worker requires no
//! state transfer beyond the next plan.
//!
//! Fault tolerance (see `driver`): worker death is detected by control-
//! connection EOF or heartbeat silence; the driver aborts survivors'
//! in-flight epoch, re-admits (or respawns) the dead rank, restores
//! weights + optimizer from the last fully-acknowledged checkpoint shard
//! set, rewinds the run report, and replays from that epoch.  With
//! `ckpt_every = 1` the replay is bitwise identical to the uninterrupted
//! run for open-loop schedules AND closed-loop `budget:*` runs: the
//! driver snapshots the rate controller into each shard set (rank 0's
//! residual slot) and restores it on rewind, so replayed epochs are
//! planned and observed exactly once (documented in README).
//!
//! Determinism across transports: for identical configs, a tcp run and an
//! in-process run produce bitwise-identical weights.  Per-position f32
//! gradient accumulation is order-independent across parameters, and the
//! driver sums worker gradient vectors in rank order — exactly the
//! in-process reduction order; compression masks and failure coins are
//! key-derived from (seed, epoch, layer, sender, receiver), not from
//! arrival order.  `tests/dist_equivalence.rs` pins this.
//!
//! [`TcpTransport`]: crate::comm::TcpTransport

pub mod driver;
pub mod protocol;
pub mod worker;

pub use driver::{run_driver, DistRun, DriverOptions};
pub use worker::{run_worker, CrashBehavior, WorkerOptions};

use crate::comm::TcpOptions;
use crate::comm::LinkModel;
use crate::compress::{
    BudgetController, LinkAwareBudgetController, OpenLoopController, RateAlloc, RateController,
};
use crate::config::TrainConfig;
use crate::coordinator::trainer::RunSetup;
use crate::engine::{ModelDims, ModelSpec};
use crate::graph::Dataset;
use crate::model::build_spec;
use crate::partition::WorkerGraph;
use crate::Result;
use std::time::Duration;

/// Everything a dist process deterministically rebuilds from the config.
pub(crate) struct DistContext {
    pub(crate) dataset: Dataset,
    pub(crate) spec: ModelSpec,
    pub(crate) setup: RunSetup,
    pub(crate) worker_graphs: Vec<WorkerGraph>,
    /// full-graph part assignment — sampled mode restricts it per epoch
    pub(crate) partition: crate::partition::Partition,
    pub(crate) q: usize,
}

impl DistContext {
    pub(crate) fn build(cfg: &TrainConfig) -> Result<DistContext> {
        anyhow::ensure!(
            cfg.engine == "native",
            "the multi-process runtime supports engine=native only (got {:?})",
            cfg.engine
        );
        anyhow::ensure!(
            !cfg.overlap,
            "the multi-process runtime uses the fused layer schedule; run with overlap=off \
             (results are bitwise identical either way)"
        );
        anyhow::ensure!(cfg.layers >= 1, "layers must be >= 1");
        anyhow::ensure!(
            !(cfg.staleness > 0 && cfg.replication > 1),
            "staleness > 0 is incompatible with replication > 1 (mirror refreshes would \
             bypass the historical cache's ledger accounting)"
        );
        // resolve eagerly so fanout/mode mistakes fail at startup, not at
        // the first sampled epoch
        cfg.sampling_config()?;
        let dataset = Dataset::load(&cfg.dataset, cfg.nodes, cfg.seed)?;
        let partitioner = crate::partition::by_name(&cfg.partitioner, cfg.seed)?;
        let partition = partitioner.partition(&dataset.graph, cfg.q)?;
        let worker_graphs = WorkerGraph::build_all(&dataset.graph, &partition)?;
        let dims = ModelDims {
            f_in: dataset.f_in(),
            hidden: cfg.hidden,
            classes: dataset.classes,
            layers: cfg.layers,
        };
        let spec = build_spec(&cfg.model, &dims)?;
        let setup = RunSetup::build(
            &dataset,
            &worker_graphs,
            &spec,
            crate::partition::PlanMode::parse(&cfg.plan)?,
            cfg.replication,
        )?;
        Ok(DistContext { dataset, spec, setup, worker_graphs, partition, q: cfg.q })
    }
}

/// FNV-1a over the training-semantic config fields.  Runtime plumbing
/// (addresses, timeouts, checkpoint cadence, crash injection) is
/// deliberately excluded: a respawned worker with crash injection cleared
/// must still hash-match the driver.
pub fn config_hash(cfg: &TrainConfig) -> u64 {
    let canon = format!(
        "{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}",
        cfg.dataset,
        cfg.nodes,
        cfg.q,
        cfg.partitioner,
        cfg.comm,
        cfg.compressor,
        cfg.engine,
        cfg.epochs,
        cfg.hidden,
        cfg.layers,
        cfg.model,
        cfg.optimizer,
        cfg.lr,
        cfg.weight_decay,
        cfg.seed,
        cfg.eval_every,
        cfg.drop_prob,
        cfg.stale_prob,
        cfg.overlap,
        cfg.plan,
        cfg.replication,
        cfg.mode,
        cfg.batch_size,
        cfg.fanout,
        cfg.staleness,
    );
    let mut h: u64 = 0xcbf29ce484222325;
    for b in canon.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Data-plane socket options from the config's timeout knobs.
pub(crate) fn tcp_options(cfg: &TrainConfig) -> TcpOptions {
    TcpOptions {
        connect_timeout: Duration::from_millis(cfg.connect_timeout_ms),
        read_timeout: Duration::from_millis(cfg.read_timeout_ms),
        ..TcpOptions::default()
    }
}

/// The rate controller for a run: `budget:*` comm specs are closed-loop,
/// everything else replays the named open-loop schedule.  Mirrors
/// `config::build_trainer_with_dataset`.
pub(crate) fn build_controller(cfg: &TrainConfig) -> Result<Box<dyn RateController>> {
    Ok(match cfg.budget_spec()? {
        Some((bytes, c_max, RateAlloc::Uniform)) => {
            Box::new(BudgetController::new(bytes, cfg.epochs, cfg.layers, c_max))
        }
        Some((bytes, c_max, RateAlloc::LinkAware)) => Box::new(LinkAwareBudgetController::new(
            bytes,
            cfg.epochs,
            cfg.layers,
            c_max,
            cfg.q,
            LinkModel::ten_gbe(),
        )),
        None => Box::new(OpenLoopController::new(cfg.comm_mode()?)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_hash_tracks_semantics_not_runtime() {
        let a = TrainConfig::default_quickstart();
        let mut b = a.clone();
        b.driver_addr = "127.0.0.1:9999".into();
        b.heartbeat_ms = 17;
        b.ckpt_every = 3;
        b.crash_at = "2:1".into();
        b.max_restarts = 9;
        b.transport = "tcp".into();
        assert_eq!(config_hash(&a), config_hash(&b), "runtime keys must not affect the hash");
        let mut c = a.clone();
        c.lr = 0.5;
        assert_ne!(config_hash(&a), config_hash(&c));
        let mut d = a.clone();
        d.seed = 77;
        assert_ne!(config_hash(&a), config_hash(&d));
    }

    #[test]
    fn dist_context_rejects_non_native_and_overlap() {
        let mut cfg = TrainConfig::default_quickstart();
        cfg.engine = "pjrt".into();
        assert!(DistContext::build(&cfg).is_err());
        cfg.engine = "native".into();
        cfg.overlap = true;
        assert!(DistContext::build(&cfg).is_err());
        cfg.overlap = false;
        let ctx = DistContext::build(&cfg).unwrap();
        assert_eq!(ctx.q, 2);
        assert_eq!(ctx.worker_graphs.len(), 2);
    }
}
