//! Multi-process training runtime: a driver process plus one worker
//! process per rank, speaking the [`protocol`] control channel, with the
//! data plane (halo exchanges) carried by [`TcpTransport`] links between
//! workers.
//!
//! The design principle is **deterministic reconstruction**: every
//! process rebuilds the complete run setup — dataset, partition, send
//! plans, model spec — from the shared config via [`DistContext::build`],
//! so only mutable state crosses the wire.  The driver owns all of it
//! (weights, optimizer, rate controller, evaluation, the run report);
//! workers are stateless across epochs because each [`protocol::Ctrl::Plan`]
//! carries the full flat weight vector.  That statelessness is the whole
//! crash-recovery story: re-admitting a restarted worker requires no
//! state transfer beyond the next plan.
//!
//! Fault tolerance (see `driver`): worker death is detected by control-
//! connection EOF or heartbeat silence; the driver aborts survivors'
//! in-flight epoch, re-admits (or respawns) the dead rank, restores
//! weights + optimizer from the last fully-acknowledged checkpoint shard
//! set, rewinds the run report, and replays from that epoch.  With
//! `ckpt_every = 1` the replay is bitwise identical to the uninterrupted
//! run for open-loop schedules AND closed-loop `budget:*` runs: the
//! driver snapshots the rate controller into each shard set (rank 0's
//! residual slot) and restores it on rewind, so replayed epochs are
//! planned and observed exactly once (documented in README).
//!
//! Determinism across transports: for identical configs, a tcp run and an
//! in-process run produce bitwise-identical weights.  Per-position f32
//! gradient accumulation is order-independent across parameters, and the
//! driver sums worker gradient vectors in rank order — exactly the
//! in-process reduction order; compression masks and failure coins are
//! key-derived from (seed, epoch, layer, sender, receiver), not from
//! arrival order.  `tests/dist_equivalence.rs` pins this.
//!
//! [`TcpTransport`]: crate::comm::TcpTransport

pub mod driver;
pub mod protocol;
pub mod worker;

pub use driver::{run_driver, DistRun, DriverOptions};
pub use worker::{run_worker, CrashBehavior, WorkerOptions};

use crate::comm::TcpOptions;
use crate::comm::LinkModel;
use crate::compress::{
    BudgetController, LinkAwareBudgetController, OpenLoopController, RateAlloc, RateController,
};
use crate::config::TrainConfig;
use crate::coordinator::trainer::RunSetup;
use crate::engine::{ModelDims, ModelSpec};
use crate::graph::store::GraphStore;
use crate::model::build_spec;
use crate::partition::WorkerGraph;
use crate::Result;
use std::sync::Arc;
use std::time::Duration;

/// Everything a dist process deterministically rebuilds from the config.
pub(crate) struct DistContext {
    pub(crate) store: Arc<dyn GraphStore>,
    pub(crate) spec: ModelSpec,
    pub(crate) setup: RunSetup,
    pub(crate) worker_graphs: Vec<WorkerGraph>,
    /// full-graph part assignment — sampled mode restricts it per epoch
    pub(crate) partition: crate::partition::Partition,
    pub(crate) q: usize,
}

impl DistContext {
    pub(crate) fn build(cfg: &TrainConfig) -> Result<DistContext> {
        anyhow::ensure!(
            cfg.engine == "native",
            "the multi-process runtime supports engine=native only (got {:?})",
            cfg.engine
        );
        anyhow::ensure!(
            !cfg.overlap,
            "the multi-process runtime uses the fused layer schedule; run with overlap=off \
             (results are bitwise identical either way)"
        );
        anyhow::ensure!(cfg.layers >= 1, "layers must be >= 1");
        anyhow::ensure!(
            !(cfg.staleness > 0 && cfg.replication > 1),
            "staleness > 0 is incompatible with replication > 1 (mirror refreshes would \
             bypass the historical cache's ledger accounting)"
        );
        // resolve eagerly so fanout/mode mistakes fail at startup, not at
        // the first sampled epoch
        cfg.sampling_config()?;
        let store = crate::config::open_store(cfg)?;
        let partitioner = crate::partition::by_name(&cfg.partitioner, cfg.seed)?;
        let partition = partitioner.partition(store.adj(), cfg.q)?;
        let worker_graphs = WorkerGraph::build_all(store.adj(), &partition)?;
        let dims = ModelDims {
            f_in: store.f_in(),
            hidden: cfg.hidden,
            classes: store.classes(),
            layers: cfg.layers,
        };
        let spec = build_spec(&cfg.model, &dims)?;
        // sampled mode swaps in a mini-batch view before epoch 0, so the
        // skeleton setup never materializes the full feature matrix
        let setup = RunSetup::build_from_store(
            store.as_ref(),
            &worker_graphs,
            &spec,
            crate::partition::PlanMode::parse(&cfg.plan)?,
            cfg.replication,
            cfg.mode != "sampled",
        )?;
        Ok(DistContext { store, spec, setup, worker_graphs, partition, q: cfg.q })
    }
}

/// FNV-1a over the training-semantic config fields.  Runtime plumbing
/// (addresses, timeouts, checkpoint cadence, crash injection) is
/// deliberately excluded: a respawned worker with crash injection cleared
/// must still hash-match the driver.  `store_path` is runtime plumbing
/// too — driver and workers may see the shards under different paths;
/// shard *content* is admitted separately by [`admission_hash`].
pub fn config_hash(cfg: &TrainConfig) -> u64 {
    let canon = format!(
        "{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}|{}",
        cfg.dataset,
        cfg.nodes,
        cfg.q,
        cfg.partitioner,
        cfg.comm,
        cfg.compressor,
        cfg.engine,
        cfg.epochs,
        cfg.hidden,
        cfg.layers,
        cfg.model,
        cfg.optimizer,
        cfg.lr,
        cfg.weight_decay,
        cfg.seed,
        cfg.eval_every,
        cfg.drop_prob,
        cfg.stale_prob,
        cfg.overlap,
        cfg.plan,
        cfg.replication,
        cfg.mode,
        cfg.batch_size,
        cfg.fanout,
        cfg.staleness,
        cfg.store,
    );
    let mut h: u64 = 0xcbf29ce484222325;
    for b in canon.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The hash a worker must present to join a run: [`config_hash`] mixed
/// with the shard manifest's content hash when the run trains out of
/// core.  Every process verifies its shard directory at open, so a
/// driver and a worker pointed at different (or stale) shard builds fail
/// admission instead of silently training on diverged graphs.
pub fn admission_hash(cfg: &TrainConfig) -> Result<u64> {
    let mut h = config_hash(cfg);
    if cfg.store == "mmap" {
        anyhow::ensure!(
            !cfg.store_path.is_empty(),
            "store = mmap needs store_path = <shard directory>"
        );
        let manifest = crate::graph::io::ShardManifest::load(std::path::Path::new(
            &cfg.store_path,
        ))?;
        for b in manifest.content_hash().to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    Ok(h)
}

/// Data-plane socket options from the config's timeout knobs.
pub(crate) fn tcp_options(cfg: &TrainConfig) -> TcpOptions {
    TcpOptions {
        connect_timeout: Duration::from_millis(cfg.connect_timeout_ms),
        read_timeout: Duration::from_millis(cfg.read_timeout_ms),
        ..TcpOptions::default()
    }
}

/// The rate controller for a run: `budget:*` comm specs are closed-loop,
/// everything else replays the named open-loop schedule.  Mirrors
/// `config::build_trainer_with_dataset`.
pub(crate) fn build_controller(cfg: &TrainConfig) -> Result<Box<dyn RateController>> {
    Ok(match cfg.budget_spec()? {
        Some((bytes, c_max, RateAlloc::Uniform)) => {
            Box::new(BudgetController::new(bytes, cfg.epochs, cfg.layers, c_max))
        }
        Some((bytes, c_max, RateAlloc::LinkAware)) => Box::new(LinkAwareBudgetController::new(
            bytes,
            cfg.epochs,
            cfg.layers,
            c_max,
            cfg.q,
            LinkModel::ten_gbe(),
        )),
        None => Box::new(OpenLoopController::new(cfg.comm_mode()?)),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_hash_tracks_semantics_not_runtime() {
        let a = TrainConfig::default_quickstart();
        let mut b = a.clone();
        b.driver_addr = "127.0.0.1:9999".into();
        b.heartbeat_ms = 17;
        b.ckpt_every = 3;
        b.crash_at = "2:1".into();
        b.max_restarts = 9;
        b.transport = "tcp".into();
        assert_eq!(config_hash(&a), config_hash(&b), "runtime keys must not affect the hash");
        let mut c = a.clone();
        c.lr = 0.5;
        assert_ne!(config_hash(&a), config_hash(&c));
        let mut d = a.clone();
        d.seed = 77;
        assert_ne!(config_hash(&a), config_hash(&d));
    }

    #[test]
    fn admission_hash_tracks_shard_content_not_location() {
        use crate::graph::{io::write_shards, Dataset};
        use crate::util::testing::TempDir;
        let ds = Dataset::load("karate-like", 0, 1).unwrap();
        let dir_a = TempDir::new().unwrap();
        let dir_b = TempDir::new().unwrap();
        write_shards(&ds, dir_a.path(), 16).unwrap();
        write_shards(&ds, dir_b.path(), 16).unwrap();
        let mut cfg = TrainConfig::default_quickstart();
        let resident = admission_hash(&cfg).unwrap();
        assert_eq!(resident, config_hash(&cfg), "resident admission is the config hash");
        cfg.store = "mmap".into();
        assert_ne!(config_hash(&cfg), admission_hash(&TrainConfig::default_quickstart()).unwrap());
        cfg.store_path = dir_a.path().to_string_lossy().into_owned();
        let ha = admission_hash(&cfg).unwrap();
        assert_ne!(ha, resident, "the store backend joins the admission hash");
        // the same build in a different directory admits identically
        cfg.store_path = dir_b.path().to_string_lossy().into_owned();
        assert_eq!(admission_hash(&cfg).unwrap(), ha);
        // a different shard build (other dataset seed) is rejected
        let other = Dataset::load("karate-like", 0, 2).unwrap();
        let dir_c = TempDir::new().unwrap();
        write_shards(&other, dir_c.path(), 16).unwrap();
        cfg.store_path = dir_c.path().to_string_lossy().into_owned();
        assert_ne!(admission_hash(&cfg).unwrap(), ha);
        // missing path is an error, not a silent resident fallback
        cfg.store_path.clear();
        assert!(admission_hash(&cfg).is_err());
    }

    #[test]
    fn dist_context_rejects_non_native_and_overlap() {
        let mut cfg = TrainConfig::default_quickstart();
        cfg.engine = "pjrt".into();
        assert!(DistContext::build(&cfg).is_err());
        cfg.engine = "native".into();
        cfg.overlap = true;
        assert!(DistContext::build(&cfg).is_err());
        cfg.overlap = false;
        let ctx = DistContext::build(&cfg).unwrap();
        assert_eq!(ctx.q, 2);
        assert_eq!(ctx.worker_graphs.len(), 2);
    }
}
