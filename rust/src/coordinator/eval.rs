//! Centralized full-graph evaluation.
//!
//! The paper reports test accuracy of the learned model; evaluation is
//! standard centralized inference (the model is identical on every worker
//! after averaging).  This runs the exact sparse forward of the model's
//! [`ModelSpec`] on the whole graph — it is NOT on the training hot path
//! and is engine-independent, which also makes it the neutral referee
//! between engines.

use crate::graph::Dataset;
use crate::model::{Aggregation, ModelSpec, Update, Weights};
use crate::partition::worker_graph::SparseBlock;
use crate::tensor::Matrix;
use crate::Result;

/// Full-graph evaluator (owns the spec's normalized adjacency operators).
pub struct FullGraphEval {
    spec: ModelSpec,
    /// mean-normalized operator (rows sum to 1), built when any layer
    /// aggregates with `Mean`
    s_mean: Option<SparseBlock>,
    /// GCN symmetric-normalized operator + per-node self-loop coefficient
    s_gcn: Option<(SparseBlock, Vec<f32>)>,
    /// unit-weight sum operator (GIN)
    s_sum: Option<SparseBlock>,
    features: Matrix,
    labels: Vec<u32>,
    m_train: Vec<f32>,
    m_val: Vec<f32>,
    m_test: Vec<f32>,
    pub n_train: usize,
    pub n_val: usize,
    pub n_test: usize,
}

/// Accuracy triple for (train, val, test).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalResult {
    pub train_acc: f32,
    pub val_acc: f32,
    pub test_acc: f32,
    pub loss: f32,
}

impl FullGraphEval {
    pub fn new(ds: &Dataset, spec: impl Into<ModelSpec>) -> FullGraphEval {
        let spec = spec.into();
        let g = &ds.graph;
        let need = |kind: Aggregation| spec.layers.iter().any(|l| l.agg == kind);
        let block = |values: Vec<f32>| SparseBlock {
            rows: g.n,
            cols: g.n,
            indptr: g.indptr.clone(),
            indices: g.indices.clone(),
            values,
        };
        let s_mean = need(Aggregation::Mean).then(|| {
            let mut values = Vec::with_capacity(g.indices.len());
            for u in 0..g.n {
                let deg = g.degree(u).max(1) as f32;
                for _ in g.neighbors(u) {
                    values.push(1.0 / deg);
                }
            }
            block(values)
        });
        let s_gcn = need(Aggregation::GcnSym).then(|| {
            let inv_sqrt: Vec<f32> =
                (0..g.n).map(|u| 1.0 / ((g.degree(u) + 1) as f32).sqrt()).collect();
            let mut values = Vec::with_capacity(g.indices.len());
            for u in 0..g.n {
                for &v in g.neighbors(u) {
                    values.push(inv_sqrt[u] * inv_sqrt[v as usize]);
                }
            }
            let coeff: Vec<f32> = (0..g.n).map(|u| 1.0 / (g.degree(u) + 1) as f32).collect();
            (block(values), coeff)
        });
        let s_sum = need(Aggregation::GinSum).then(|| block(vec![1.0; g.indices.len()]));
        let (m_train, m_val, m_test) = ds.split.as_f32();
        FullGraphEval {
            spec,
            s_mean,
            s_gcn,
            s_sum,
            features: ds.features.clone(),
            labels: ds.labels.clone(),
            n_train: m_train.iter().filter(|&&x| x > 0.0).count(),
            n_val: m_val.iter().filter(|&&x| x > 0.0).count(),
            n_test: m_test.iter().filter(|&&x| x > 0.0).count(),
            m_train,
            m_val,
            m_test,
        }
    }

    /// Exact centralized forward -> logits, per the spec's contract.
    pub fn logits(&self, weights: &Weights) -> Matrix {
        let mut h = self.features.clone();
        for (l, ls) in self.spec.layers.iter().enumerate() {
            let mut agg = Matrix::zeros(h.rows, h.cols);
            match ls.agg {
                Aggregation::Mean => {
                    self.s_mean.as_ref().expect("mean op built").spmm_into(&h, &mut agg)
                }
                Aggregation::GcnSym => {
                    let (s, coeff) = self.s_gcn.as_ref().expect("gcn op built");
                    for (r, &c) in coeff.iter().enumerate() {
                        let hrow = h.row(r);
                        for (a, &v) in agg.row_mut(r).iter_mut().zip(hrow) {
                            *a += c * v;
                        }
                    }
                    s.spmm_into(&h, &mut agg);
                }
                Aggregation::GinSum => {
                    self.s_sum.as_ref().expect("sum op built").spmm_into(&h, &mut agg)
                }
            }
            let lw = &weights.layers[l];
            let mut pre = match ls.update {
                Update::SageLinear => {
                    let mut pre = h.matmul(&lw.params[0].value);
                    pre.add_assign(&agg.matmul(&lw.params[1].value));
                    pre.add_row_broadcast(&lw.params[2].value.data);
                    pre
                }
                Update::GcnLinear => {
                    let mut pre = agg.matmul(&lw.params[0].value);
                    pre.add_row_broadcast(&lw.params[1].value.data);
                    pre
                }
                Update::GinMlp => {
                    let eps = lw.params[0].value.data[0];
                    let s = 1.0 + eps;
                    let mut z = agg;
                    for (zv, &hv) in z.data.iter_mut().zip(&h.data) {
                        *zv += s * hv;
                    }
                    let mut m = z.matmul(&lw.params[1].value);
                    m.add_row_broadcast(&lw.params[2].value.data);
                    m.relu();
                    let mut pre = m.matmul(&lw.params[3].value);
                    pre.add_row_broadcast(&lw.params[4].value.data);
                    pre
                }
            };
            ls.act.apply(&mut pre);
            h = pre;
        }
        h
    }

    /// Full evaluation: accuracies on the three splits + train loss.
    pub fn evaluate(&self, weights: &Weights) -> Result<EvalResult> {
        let logits = self.logits(weights);
        let out = crate::engine::native::loss_grad_dense(
            &logits,
            &self.labels,
            &self.m_train,
            &self.m_val,
            &self.m_test,
        )?;
        Ok(EvalResult {
            train_acc: crate::metrics::accuracy(out.correct_train, self.n_train),
            val_acc: crate::metrics::accuracy(out.correct_val, self.n_val),
            test_acc: crate::metrics::accuracy(out.correct_test, self.n_test),
            loss: out.loss,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{build_spec, ModelDims, MODELS};

    #[test]
    fn eval_counts_splits() {
        let ds = Dataset::load("karate-like", 0, 1).unwrap();
        let dims = ModelDims { f_in: ds.f_in(), hidden: 8, classes: ds.classes, layers: 3 };
        let ev = FullGraphEval::new(&ds, &dims);
        assert_eq!(ev.n_train + ev.n_val + ev.n_test, ds.n());
    }

    #[test]
    fn eval_runs_and_is_deterministic_for_every_model() {
        let ds = Dataset::load("karate-like", 0, 2).unwrap();
        let dims = ModelDims { f_in: ds.f_in(), hidden: 8, classes: ds.classes, layers: 3 };
        for &name in MODELS {
            let spec = build_spec(name, &dims).unwrap();
            let w = Weights::glorot(&spec, 3);
            let ev = FullGraphEval::new(&ds, &spec);
            let a = ev.evaluate(&w).unwrap();
            let b = ev.evaluate(&w).unwrap();
            assert_eq!(a, b, "{name}");
            assert!(a.test_acc >= 0.0 && a.test_acc <= 1.0, "{name}");
            assert!(a.loss.is_finite(), "{name}");
        }
    }

    #[test]
    fn random_weights_near_chance() {
        let ds = Dataset::load("karate-like", 0, 5).unwrap();
        let dims = ModelDims { f_in: ds.f_in(), hidden: 8, classes: ds.classes, layers: 3 };
        let ev = FullGraphEval::new(&ds, &dims);
        // average over a few seeds: near 50% for 2 classes
        let mut acc = 0.0;
        for seed in 0..5 {
            acc += ev.evaluate(&Weights::glorot(&dims, seed)).unwrap().test_acc;
        }
        acc /= 5.0;
        assert!((0.15..0.85).contains(&acc), "suspicious chance accuracy {acc}");
    }
}
