//! Centralized full-graph evaluation.
//!
//! The paper reports test accuracy of the learned model; evaluation is
//! standard centralized inference (the model is identical on every worker
//! after averaging).  This runs the exact sparse forward of the model's
//! [`ModelSpec`] on the whole graph — it is NOT on the training hot path
//! and is engine-independent, which also makes it the neutral referee
//! between engines.
//!
//! The input layer streams: features come from a [`GraphStore`] and the
//! layer-0 forward works in fixed row blocks, gathering only each block's
//! own rows plus its neighbor union.  A resident backend pays one small
//! scratch copy; an out-of-core backend never materializes the dense
//! `n x f_in` matrix at all.  Both run the identical code path, so
//! `store=mmap` evaluation is bitwise equal to `store=resident`.

use std::sync::Arc;

use crate::graph::store::{GraphStore, ResidentStore};
use crate::graph::Dataset;
use crate::model::{Aggregation, ModelSpec, Update, Weights};
use crate::partition::worker_graph::SparseBlock;
use crate::tensor::Matrix;
use crate::Result;

/// Rows per streamed layer-0 block.  Any value yields bitwise-identical
/// logits (each output row accumulates independently in nz order); this
/// only bounds the gather scratch.
const EVAL_BLOCK_ROWS: usize = 512;

/// Full-graph evaluator (owns the spec's normalized adjacency operators).
pub struct FullGraphEval {
    spec: ModelSpec,
    store: Arc<dyn GraphStore>,
    /// mean-normalized operator (rows sum to 1), built when any layer
    /// aggregates with `Mean`
    s_mean: Option<SparseBlock>,
    /// GCN symmetric-normalized operator + per-node self-loop coefficient
    s_gcn: Option<(SparseBlock, Vec<f32>)>,
    /// unit-weight sum operator (GIN)
    s_sum: Option<SparseBlock>,
    labels: Vec<u32>,
    m_train: Vec<f32>,
    m_val: Vec<f32>,
    m_test: Vec<f32>,
    pub n_train: usize,
    pub n_val: usize,
    pub n_test: usize,
}

/// Accuracy triple for (train, val, test).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalResult {
    pub train_acc: f32,
    pub val_acc: f32,
    pub test_acc: f32,
    pub loss: f32,
}

impl FullGraphEval {
    /// Resident-dataset convenience wrapper (clones `ds` into a store).
    pub fn new(ds: &Dataset, spec: impl Into<ModelSpec>) -> FullGraphEval {
        FullGraphEval::from_store(Arc::new(ResidentStore::new(ds.clone())), spec)
            .expect("resident store construction cannot fail")
    }

    /// Build the evaluator against any store backend.  Adjacency is read
    /// once to build the normalized operators (nz values identical to the
    /// old `Csr`-based construction: same neighbor order, same degrees).
    pub fn from_store(
        store: Arc<dyn GraphStore>,
        spec: impl Into<ModelSpec>,
    ) -> Result<FullGraphEval> {
        let spec = spec.into();
        let n = store.n_nodes();
        let need = |kind: Aggregation| spec.layers.iter().any(|l| l.agg == kind);

        // one adjacency pass shared by every operator
        let mut indptr = Vec::with_capacity(n + 1);
        indptr.push(0u64);
        let mut indices: Vec<u32> = Vec::new();
        let mut nbrs = Vec::new();
        for u in 0..n {
            store.neighbors_into(u, &mut nbrs);
            indices.extend_from_slice(&nbrs);
            indptr.push(indices.len() as u64);
        }
        let degree = |u: usize| (indptr[u + 1] - indptr[u]) as usize;
        let block = |values: Vec<f32>| SparseBlock {
            rows: n,
            cols: n,
            indptr: indptr.clone(),
            indices: indices.clone(),
            values,
        };

        let s_mean = need(Aggregation::Mean).then(|| {
            let mut values = Vec::with_capacity(indices.len());
            for u in 0..n {
                let deg = degree(u).max(1) as f32;
                for _ in 0..degree(u) {
                    values.push(1.0 / deg);
                }
            }
            block(values)
        });
        let s_gcn = need(Aggregation::GcnSym).then(|| {
            let inv_sqrt: Vec<f32> =
                (0..n).map(|u| 1.0 / ((degree(u) + 1) as f32).sqrt()).collect();
            let mut values = Vec::with_capacity(indices.len());
            for u in 0..n {
                let lo = indptr[u] as usize;
                let hi = indptr[u + 1] as usize;
                for &v in &indices[lo..hi] {
                    values.push(inv_sqrt[u] * inv_sqrt[v as usize]);
                }
            }
            let coeff: Vec<f32> = (0..n).map(|u| 1.0 / (degree(u) + 1) as f32).collect();
            (block(values), coeff)
        });
        let s_sum = need(Aggregation::GinSum).then(|| block(vec![1.0; indices.len()]));

        let all: Vec<u32> = (0..n as u32).collect();
        let mut labels = Vec::new();
        store.gather_labels(&all, &mut labels)?;
        let (m_train, m_val, m_test) = store.split().as_f32();
        Ok(FullGraphEval {
            spec,
            store,
            s_mean,
            s_gcn,
            s_sum,
            labels,
            n_train: m_train.iter().filter(|&&x| x > 0.0).count(),
            n_val: m_val.iter().filter(|&&x| x > 0.0).count(),
            n_test: m_test.iter().filter(|&&x| x > 0.0).count(),
            m_train,
            m_val,
            m_test,
        })
    }

    fn op(&self, agg: Aggregation) -> &SparseBlock {
        match agg {
            Aggregation::Mean => self.s_mean.as_ref().expect("mean op built"),
            Aggregation::GcnSym => &self.s_gcn.as_ref().expect("gcn op built").0,
            Aggregation::GinSum => self.s_sum.as_ref().expect("sum op built"),
        }
    }

    /// Streamed layer-0 forward: gather each block's own rows + neighbor
    /// union, aggregate per output row in exact nz order, apply the
    /// layer's update row-block-wise.  Per-row accumulation matches
    /// `SparseBlock::spmm_into` element for element, so block size never
    /// changes a bit of the output.
    fn layer0(&self, weights: &Weights) -> Result<Matrix> {
        let ls = &self.spec.layers[0];
        let lw = &weights.layers[0];
        let n = self.store.n_nodes();
        let f = self.store.f_in();
        let s = self.op(ls.agg);
        let gcn_coeff = self.s_gcn.as_ref().map(|(_, c)| c);
        let out_cols = match ls.update {
            Update::SageLinear => lw.params[0].value.cols,
            Update::GcnLinear => lw.params[0].value.cols,
            Update::GinMlp => lw.params[3].value.cols,
        };
        let mut pre = Matrix::zeros(n, out_cols);
        let mut x_own = Matrix::zeros(0, 0);
        let mut x_nb = Matrix::zeros(0, 0);
        let mut r0 = 0usize;
        while r0 < n {
            let r1 = (r0 + EVAL_BLOCK_ROWS).min(n);
            let b = r1 - r0;
            let own: Vec<u32> = (r0 as u32..r1 as u32).collect();
            self.store.gather_rows(&own, &mut x_own)?;
            // sorted-unique union of the block's aggregation columns
            let lo = s.indptr[r0] as usize;
            let hi = s.indptr[r1] as usize;
            let mut cols: Vec<u32> = s.indices[lo..hi].to_vec();
            cols.sort_unstable();
            cols.dedup();
            self.store.gather_rows(&cols, &mut x_nb)?;

            let mut agg = Matrix::zeros(b, f);
            for i in 0..b {
                let r = r0 + i;
                let out_row = agg.row_mut(i);
                // GCN adds its self-loop term before the neighbor sum,
                // exactly as the dense-path code did
                if ls.agg == Aggregation::GcnSym {
                    let c = gcn_coeff.expect("gcn coeff built")[r];
                    for (o, &v) in out_row.iter_mut().zip(x_own.row(i)) {
                        *o += c * v;
                    }
                }
                let lo = s.indptr[r] as usize;
                let hi = s.indptr[r + 1] as usize;
                for (k, &c) in s.indices[lo..hi].iter().enumerate() {
                    let w = s.values[lo + k];
                    let pos = cols.binary_search(&c).expect("gathered column");
                    for (o, &xv) in out_row.iter_mut().zip(x_nb.row(pos)) {
                        *o += w * xv;
                    }
                }
            }

            let pre_block = match ls.update {
                Update::SageLinear => {
                    let mut p = x_own.matmul(&lw.params[0].value);
                    p.add_assign(&agg.matmul(&lw.params[1].value));
                    p.add_row_broadcast(&lw.params[2].value.data);
                    p
                }
                Update::GcnLinear => {
                    let mut p = agg.matmul(&lw.params[0].value);
                    p.add_row_broadcast(&lw.params[1].value.data);
                    p
                }
                Update::GinMlp => {
                    let eps = lw.params[0].value.data[0];
                    let sc = 1.0 + eps;
                    let mut z = agg;
                    for (zv, &hv) in z.data.iter_mut().zip(&x_own.data) {
                        *zv += sc * hv;
                    }
                    let mut m = z.matmul(&lw.params[1].value);
                    m.add_row_broadcast(&lw.params[2].value.data);
                    m.relu();
                    let mut p = m.matmul(&lw.params[3].value);
                    p.add_row_broadcast(&lw.params[4].value.data);
                    p
                }
            };
            pre.data[r0 * out_cols..r1 * out_cols].copy_from_slice(&pre_block.data);
            r0 = r1;
        }
        let mut h = pre;
        self.spec.layers[0].act.apply(&mut h);
        Ok(h)
    }

    /// Exact centralized forward -> logits, per the spec's contract.
    pub fn logits(&self, weights: &Weights) -> Result<Matrix> {
        let mut h = self.layer0(weights)?;
        for (l, ls) in self.spec.layers.iter().enumerate().skip(1) {
            let mut agg = Matrix::zeros(h.rows, h.cols);
            match ls.agg {
                Aggregation::Mean => {
                    self.s_mean.as_ref().expect("mean op built").spmm_into(&h, &mut agg)
                }
                Aggregation::GcnSym => {
                    let (s, coeff) = self.s_gcn.as_ref().expect("gcn op built");
                    for (r, &c) in coeff.iter().enumerate() {
                        let hrow = h.row(r);
                        for (a, &v) in agg.row_mut(r).iter_mut().zip(hrow) {
                            *a += c * v;
                        }
                    }
                    s.spmm_into(&h, &mut agg);
                }
                Aggregation::GinSum => {
                    self.s_sum.as_ref().expect("sum op built").spmm_into(&h, &mut agg)
                }
            }
            let lw = &weights.layers[l];
            let mut pre = match ls.update {
                Update::SageLinear => {
                    let mut pre = h.matmul(&lw.params[0].value);
                    pre.add_assign(&agg.matmul(&lw.params[1].value));
                    pre.add_row_broadcast(&lw.params[2].value.data);
                    pre
                }
                Update::GcnLinear => {
                    let mut pre = agg.matmul(&lw.params[0].value);
                    pre.add_row_broadcast(&lw.params[1].value.data);
                    pre
                }
                Update::GinMlp => {
                    let eps = lw.params[0].value.data[0];
                    let s = 1.0 + eps;
                    let mut z = agg;
                    for (zv, &hv) in z.data.iter_mut().zip(&h.data) {
                        *zv += s * hv;
                    }
                    let mut m = z.matmul(&lw.params[1].value);
                    m.add_row_broadcast(&lw.params[2].value.data);
                    m.relu();
                    let mut pre = m.matmul(&lw.params[3].value);
                    pre.add_row_broadcast(&lw.params[4].value.data);
                    pre
                }
            };
            ls.act.apply(&mut pre);
            h = pre;
        }
        Ok(h)
    }

    /// Full evaluation: accuracies on the three splits + train loss.
    pub fn evaluate(&self, weights: &Weights) -> Result<EvalResult> {
        let logits = self.logits(weights)?;
        let out = crate::engine::native::loss_grad_dense(
            &logits,
            &self.labels,
            &self.m_train,
            &self.m_val,
            &self.m_test,
        )?;
        Ok(EvalResult {
            train_acc: crate::metrics::accuracy(out.correct_train, self.n_train),
            val_acc: crate::metrics::accuracy(out.correct_val, self.n_val),
            test_acc: crate::metrics::accuracy(out.correct_test, self.n_test),
            loss: out.loss,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::io::write_shards;
    use crate::graph::MmapStore;
    use crate::model::{build_spec, ModelDims, MODELS};
    use crate::util::testing::TempDir;

    #[test]
    fn eval_counts_splits() {
        let ds = Dataset::load("karate-like", 0, 1).unwrap();
        let dims = ModelDims { f_in: ds.f_in(), hidden: 8, classes: ds.classes, layers: 3 };
        let ev = FullGraphEval::new(&ds, &dims);
        assert_eq!(ev.n_train + ev.n_val + ev.n_test, ds.n());
    }

    #[test]
    fn eval_runs_and_is_deterministic_for_every_model() {
        let ds = Dataset::load("karate-like", 0, 2).unwrap();
        let dims = ModelDims { f_in: ds.f_in(), hidden: 8, classes: ds.classes, layers: 3 };
        for &name in MODELS {
            let spec = build_spec(name, &dims).unwrap();
            let w = Weights::glorot(&spec, 3);
            let ev = FullGraphEval::new(&ds, &spec);
            let a = ev.evaluate(&w).unwrap();
            let b = ev.evaluate(&w).unwrap();
            assert_eq!(a, b, "{name}");
            assert!(a.test_acc >= 0.0 && a.test_acc <= 1.0, "{name}");
            assert!(a.loss.is_finite(), "{name}");
        }
    }

    #[test]
    fn random_weights_near_chance() {
        let ds = Dataset::load("karate-like", 0, 5).unwrap();
        let dims = ModelDims { f_in: ds.f_in(), hidden: 8, classes: ds.classes, layers: 3 };
        let ev = FullGraphEval::new(&ds, &dims);
        // average over a few seeds: near 50% for 2 classes
        let mut acc = 0.0;
        for seed in 0..5 {
            acc += ev.evaluate(&Weights::glorot(&dims, seed)).unwrap().test_acc;
        }
        acc /= 5.0;
        assert!((0.15..0.85).contains(&acc), "suspicious chance accuracy {acc}");
    }

    #[test]
    fn mmap_store_eval_is_bitwise_equal_to_resident_for_every_model() {
        let ds = Dataset::load("karate-like", 0, 6).unwrap();
        let dir = TempDir::new().unwrap();
        write_shards(&ds, dir.path(), 10).unwrap();
        let ms: Arc<dyn GraphStore> = Arc::new(MmapStore::open(dir.path()).unwrap());
        let dims = ModelDims { f_in: ds.f_in(), hidden: 8, classes: ds.classes, layers: 3 };
        for &name in MODELS {
            let spec = build_spec(name, &dims).unwrap();
            let w = Weights::glorot(&spec, 11);
            let resident = FullGraphEval::new(&ds, &spec);
            let mmap = FullGraphEval::from_store(ms.clone(), &spec).unwrap();
            let a = resident.logits(&w).unwrap();
            let b = mmap.logits(&w).unwrap();
            let bits = |m: &Matrix| m.data.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&a), bits(&b), "{name} logits must be bitwise equal");
            assert_eq!(resident.evaluate(&w).unwrap(), mmap.evaluate(&w).unwrap(), "{name}");
        }
    }
}
