//! Centralized full-graph evaluation.
//!
//! The paper reports test accuracy of the learned model; evaluation is
//! standard centralized inference (the model is identical on every worker
//! after averaging).  This runs the exact sparse forward on the whole
//! graph — it is NOT on the training hot path and is engine-independent,
//! which also makes it the neutral referee between engines.

use crate::engine::{ModelDims, Weights};
use crate::graph::Dataset;
use crate::partition::worker_graph::SparseBlock;
use crate::tensor::Matrix;
use crate::Result;

/// Full-graph evaluator (owns the normalized adjacency).
pub struct FullGraphEval {
    s_full: SparseBlock,
    features: Matrix,
    labels: Vec<u32>,
    m_train: Vec<f32>,
    m_val: Vec<f32>,
    m_test: Vec<f32>,
    pub n_train: usize,
    pub n_val: usize,
    pub n_test: usize,
}

/// Accuracy triple for (train, val, test).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalResult {
    pub train_acc: f32,
    pub val_acc: f32,
    pub test_acc: f32,
    pub loss: f32,
}

impl FullGraphEval {
    pub fn new(ds: &Dataset) -> FullGraphEval {
        let g = &ds.graph;
        let mut indptr = Vec::with_capacity(g.n + 1);
        let mut values = Vec::with_capacity(g.indices.len());
        indptr.push(0u64);
        for u in 0..g.n {
            let deg = g.degree(u).max(1) as f32;
            for _ in g.neighbors(u) {
                values.push(1.0 / deg);
            }
            indptr.push(g.indptr[u + 1]);
        }
        let (m_train, m_val, m_test) = ds.split.as_f32();
        FullGraphEval {
            s_full: SparseBlock {
                rows: g.n,
                cols: g.n,
                indptr,
                indices: g.indices.clone(),
                values,
            },
            features: ds.features.clone(),
            labels: ds.labels.clone(),
            n_train: m_train.iter().filter(|&&x| x > 0.0).count(),
            n_val: m_val.iter().filter(|&&x| x > 0.0).count(),
            n_test: m_test.iter().filter(|&&x| x > 0.0).count(),
            m_train,
            m_val,
            m_test,
        }
    }

    /// Exact centralized forward -> logits.
    pub fn logits(&self, dims: &ModelDims, weights: &Weights) -> Matrix {
        let mut h = self.features.clone();
        for (l, lw) in weights.layers.iter().enumerate() {
            let mut agg = Matrix::zeros(h.rows, h.cols);
            self.s_full.spmm_into(&h, &mut agg);
            let mut pre = h.matmul(&lw.w_self);
            pre.add_assign(&agg.matmul(&lw.w_neigh));
            pre.add_row_broadcast(&lw.bias);
            if l + 1 < dims.layers {
                pre.relu();
            }
            h = pre;
        }
        h
    }

    /// Full evaluation: accuracies on the three splits + train loss.
    pub fn evaluate(&self, dims: &ModelDims, weights: &Weights) -> Result<EvalResult> {
        let logits = self.logits(dims, weights);
        let out = crate::engine::native::loss_grad_dense(
            &logits,
            &self.labels,
            &self.m_train,
            &self.m_val,
            &self.m_test,
        )?;
        Ok(EvalResult {
            train_acc: crate::metrics::accuracy(out.correct_train, self.n_train),
            val_acc: crate::metrics::accuracy(out.correct_val, self.n_val),
            test_acc: crate::metrics::accuracy(out.correct_test, self.n_test),
            loss: out.loss,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_counts_splits() {
        let ds = Dataset::load("karate-like", 0, 1).unwrap();
        let ev = FullGraphEval::new(&ds);
        assert_eq!(ev.n_train + ev.n_val + ev.n_test, ds.n());
    }

    #[test]
    fn eval_runs_and_is_deterministic() {
        let ds = Dataset::load("karate-like", 0, 2).unwrap();
        let dims = ModelDims { f_in: ds.f_in(), hidden: 8, classes: ds.classes, layers: 3 };
        let w = Weights::glorot(&dims, 3);
        let ev = FullGraphEval::new(&ds);
        let a = ev.evaluate(&dims, &w).unwrap();
        let b = ev.evaluate(&dims, &w).unwrap();
        assert_eq!(a, b);
        assert!(a.test_acc >= 0.0 && a.test_acc <= 1.0);
        assert!(a.loss.is_finite());
    }

    #[test]
    fn random_weights_near_chance() {
        let ds = Dataset::load("karate-like", 0, 5).unwrap();
        let dims = ModelDims { f_in: ds.f_in(), hidden: 8, classes: ds.classes, layers: 3 };
        let ev = FullGraphEval::new(&ds);
        // average over a few seeds: near 50% for 2 classes
        let mut acc = 0.0;
        for seed in 0..5 {
            acc += ev.evaluate(&dims, &Weights::glorot(&dims, seed)).unwrap().test_acc;
        }
        acc /= 5.0;
        assert!((0.15..0.85).contains(&acc), "suspicious chance accuracy {acc}");
    }
}
