//! Minimal dense f32 matrix used by the native engine and the PJRT
//! marshalling layer.  Row-major storage with register-blocked,
//! cache-tiled kernels over scoped-thread data parallelism.
//!
//! # Kernel design
//!
//! * `matmul` / `matmul_into` — the inner kernel holds an `MR x NR`
//!   accumulator tile in registers across the whole k loop, so each loaded
//!   B panel row is reused `MR` times and each output element is written
//!   exactly once (the naive row-streaming loop re-reads the full B row
//!   and read-modify-writes the output row once per k).  Matrices that are
//!   mostly zeros (dense blocks materialized from sparse operators) are
//!   detected with a deterministic stride probe and routed to a
//!   zero-skipping row kernel instead, where skipping beats tiling.
//! * `matmul_nt` — `A @ Bᵀ` without materializing the transpose: both
//!   operands are walked along contiguous rows (a 4-way unrolled dot
//!   product), which is exactly the shape of the backward pass's
//!   `g_pre @ Wᵀ` products.
//! * `t_matmul` — `Aᵀ @ B` as a sum of per-slab outer-product partials.
//!   Slabs are a **fixed** `T_SLAB` rows, never a function of the thread
//!   count, and partials are reduced in slab order — so results are
//!   identical for every `VARCO_THREADS` setting (the parallel trainer's
//!   bit-stability contract), merely computed faster with more threads.
//!
//! Every kernel's accumulation order depends only on the operand shapes,
//! never on the thread budget; `tests/properties.rs` pins each one against
//! a naive reference oracle.

use crate::util::parallel;

/// Register tile height (output rows held in accumulators).
const MR: usize = 4;
/// Register tile width (output columns held in accumulators).
const NR: usize = 8;
/// Rows per `t_matmul` reduction slab.  Fixed (not derived from the
/// thread count) so the slab sum order — and therefore every last bit of
/// the result — is independent of `VARCO_THREADS`.
const T_SLAB: usize = 128;
/// Slab partials materialized at once by `t_matmul` (bounds transient
/// memory at `T_WAVE * m * n` floats for tall operands).  Like `T_SLAB`,
/// a fixed constant: the wave split never changes the reduction order.
const T_WAVE: usize = 16;

/// Row-major dense f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length {} != {rows}x{cols}", data.len());
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// self @ other into a fresh matrix.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// self @ other, overwriting `out` (which may hold arbitrary scratch
    /// contents).  Parallel over `MR`-row bands of the output; per-element
    /// accumulation runs over k in ascending order for any thread count.
    pub fn matmul_into(&self, other: &Matrix, out: &mut Matrix) {
        self.matmul_range_into(other, out, 0, self.rows);
    }

    /// Row-block product: `out[r0..r1] = self[r0..r1] @ other`, leaving
    /// every other output row untouched.  Each output element is a single
    /// accumulator over k in ascending order regardless of which rows
    /// share its register tile, so computing `[0, k)` and `[k, rows)`
    /// separately is bitwise identical to one full call — the overlap
    /// pipeline's interior/boundary split depends on exactly that.  The
    /// zero-skip probe samples only the requested rows (the rest of a
    /// scratch operand may be uninitialized).
    pub fn matmul_range_into(&self, other: &Matrix, out: &mut Matrix, r0: usize, r1: usize) {
        assert_eq!(self.cols, other.rows, "matmul {}x{} @ {}x{}", self.rows, self.cols, other.rows, other.cols);
        assert_eq!(
            out.shape(),
            (self.rows, other.cols),
            "matmul out {:?} != ({}, {})",
            out.shape(),
            self.rows,
            other.cols
        );
        assert!(r0 <= r1 && r1 <= self.rows, "matmul row block {r0}..{r1} of {}", self.rows);
        let (k, n) = (self.cols, other.cols);
        if r0 == r1 || n == 0 {
            return;
        }
        if k == 0 {
            out.data[r0 * n..r1 * n].fill(0.0);
            return;
        }
        let a = &self.data;
        let b = &other.data;
        if mostly_zero(&a[r0 * k..r1 * k]) {
            // dense image of a sparse operator: skipping zero A entries
            // beats register tiling (tiling re-scans k once per column
            // tile, which multiplies the skip cost by n/NR)
            parallel::par_chunks_mut(&mut out.data[r0 * n..r1 * n], n, |i, out_row| {
                out_row.fill(0.0);
                let r = r0 + i;
                let a_row = &a[r * k..(r + 1) * k];
                for (kk, &av) in a_row.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let b_row = &b[kk * n..(kk + 1) * n];
                    for (o, &bv) in out_row.iter_mut().zip(b_row) {
                        *o += av * bv;
                    }
                }
            });
        } else {
            parallel::par_chunks_mut(&mut out.data[r0 * n..r1 * n], MR * n, |blk, out_blk| {
                let i0 = r0 + blk * MR;
                let mr = out_blk.len() / n;
                matmul_block(&a[i0 * k..(i0 + mr) * k], b, out_blk, mr, k, n);
            });
        }
    }

    /// self @ otherᵀ into a fresh matrix (no transpose materialized).
    pub fn matmul_nt(&self, other: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.rows, other.rows);
        self.matmul_nt_into(other, &mut out);
        out
    }

    /// self @ otherᵀ, overwriting `out`.  Each output element is one dot
    /// product of two contiguous rows — the backward pass's
    /// `g_pre @ Wᵀ` shape, which previously paid a full `transpose()`
    /// allocation per layer per epoch.
    pub fn matmul_nt_into(&self, other: &Matrix, out: &mut Matrix) {
        self.matmul_nt_range_into(other, out, 0, self.rows);
    }

    /// Row-block `A @ Bᵀ`: `out[r0..r1] = self[r0..r1] @ otherᵀ`, leaving
    /// every other output row untouched.  Each output element is one
    /// independent row dot, so the split is bitwise the full call — the
    /// overlap pipeline's backward halo computes only boundary rows this
    /// way before posting the gradient exchange.
    pub fn matmul_nt_range_into(&self, other: &Matrix, out: &mut Matrix, r0: usize, r1: usize) {
        assert_eq!(
            self.cols, other.cols,
            "matmul_nt {}x{} @ ({}x{})^T",
            self.rows, self.cols, other.rows, other.cols
        );
        assert_eq!(
            out.shape(),
            (self.rows, other.rows),
            "matmul_nt out {:?} != ({}, {})",
            out.shape(),
            self.rows,
            other.rows
        );
        assert!(r0 <= r1 && r1 <= self.rows, "matmul_nt row block {r0}..{r1} of {}", self.rows);
        let n = other.rows;
        if r0 == r1 || n == 0 {
            return;
        }
        parallel::par_chunks_mut(&mut out.data[r0 * n..r1 * n], n, |i, out_row| {
            let a_row = self.row(r0 + i);
            for (j, o) in out_row.iter_mut().enumerate() {
                *o = dot(a_row, other.row(j));
            }
        });
    }

    /// selfᵀ @ other without materializing the transpose: a slab-ordered
    /// sum of outer-product partials (see module docs for the determinism
    /// contract).
    pub fn t_matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "t_matmul {}x{} @ {}x{}", self.rows, self.cols, other.rows, other.cols);
        let (k, m, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        if k == 0 || m == 0 || n == 0 {
            return out;
        }
        let n_slabs = k.div_ceil(T_SLAB);
        if n_slabs == 1 {
            t_matmul_slab(&self.data, &other.data, &mut out.data, 0, k, m, n);
            return out;
        }
        // Process slabs in fixed-size waves: each wave's partials are
        // computed in parallel, then reduced into `out` in ascending slab
        // order before the next wave starts.  Transient memory is bounded
        // at T_WAVE partials (not k/T_SLAB of them), and the reduction
        // order stays slab-ascending for every wave split and thread
        // count — the sum is still a pure function of the shapes.
        let mut s0 = 0usize;
        while s0 < n_slabs {
            let wave = T_WAVE.min(n_slabs - s0);
            let partials: Vec<Vec<f32>> = parallel::par_map(wave, |i| {
                let lo = (s0 + i) * T_SLAB;
                let hi = (lo + T_SLAB).min(k);
                let mut acc = vec![0.0f32; m * n];
                t_matmul_slab(&self.data, &other.data, &mut acc, lo, hi, m, n);
                acc
            });
            for p in partials {
                for (o, v) in out.data.iter_mut().zip(p) {
                    *o += v;
                }
            }
            s0 += wave;
        }
        out
    }

    /// Deterministic stride probe: true when > 7/8 of sampled entries are
    /// zero (dense blocks built by `SparseBlock::to_dense`).
    fn mostly_zero(&self) -> bool {
        mostly_zero(&self.data)
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// Add a row vector to every row (bias broadcast).
    pub fn add_row_broadcast(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        for i in 0..self.rows {
            for (a, &b) in self.row_mut(i).iter_mut().zip(bias) {
                *a += b;
            }
        }
    }

    pub fn relu(&mut self) {
        for a in self.data.iter_mut() {
            if *a < 0.0 {
                *a = 0.0;
            }
        }
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Row-wise argmax (predictions from logits).
    pub fn argmax_rows(&self) -> Vec<u32> {
        (0..self.rows)
            .map(|i| {
                let row = self.row(i);
                let mut best = 0usize;
                for (j, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = j;
                    }
                }
                best as u32
            })
            .collect()
    }
}

/// Deterministic stride probe over a storage block: true when > 7/8 of
/// sampled entries are zero.
fn mostly_zero(data: &[f32]) -> bool {
    let step = (data.len() / 512).max(1);
    let mut seen = 0usize;
    let mut nonzero = 0usize;
    let mut i = 0;
    while i < data.len() {
        seen += 1;
        nonzero += (data[i] != 0.0) as usize;
        i += step;
    }
    seen > 0 && nonzero * 8 < seen
}

/// out (mr x n) = a (mr x k) @ b (k x n), overwriting out.  `mr <= MR`.
/// The full `MR x NR` tile is specialized so the compiler sees constant
/// trip counts; ragged edges fall through to runtime-bounded loops.  Both
/// paths accumulate over k in ascending order per output element.
fn matmul_block(a: &[f32], b: &[f32], out: &mut [f32], mr: usize, k: usize, n: usize) {
    let mut j0 = 0usize;
    while j0 < n {
        let nr = NR.min(n - j0);
        let mut acc = [[0.0f32; NR]; MR];
        if mr == MR && nr == NR {
            for kk in 0..k {
                let base = kk * n + j0;
                let brow: &[f32; NR] = (&b[base..base + NR]).try_into().unwrap();
                for r in 0..MR {
                    let av = a[r * k + kk];
                    let accr = &mut acc[r];
                    for c in 0..NR {
                        accr[c] += av * brow[c];
                    }
                }
            }
        } else {
            for kk in 0..k {
                let brow = &b[kk * n + j0..kk * n + j0 + nr];
                for r in 0..mr {
                    let av = a[r * k + kk];
                    let accr = &mut acc[r];
                    for (c, &bv) in brow.iter().enumerate() {
                        accr[c] += av * bv;
                    }
                }
            }
        }
        for r in 0..mr {
            out[r * n + j0..r * n + j0 + nr].copy_from_slice(&acc[r][..nr]);
        }
        j0 += nr;
    }
}

/// 4-way unrolled dot product (independent accumulators for ILP; the
/// reduction tree is fixed, so results depend only on the inputs).
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for (ca, cb) in a.chunks_exact(4).zip(b.chunks_exact(4)) {
        s0 += ca[0] * cb[0];
        s1 += ca[1] * cb[1];
        s2 += ca[2] * cb[2];
        s3 += ca[3] * cb[3];
    }
    let mut s = (s0 + s1) + (s2 + s3);
    for (x, y) in a.chunks_exact(4).remainder().iter().zip(b.chunks_exact(4).remainder()) {
        s += x * y;
    }
    s
}

/// acc (m x n) += a[lo..hi]ᵀ @ b[lo..hi]: rows are consumed in pairs so
/// each pass over the accumulator retires two outer products.
fn t_matmul_slab(a: &[f32], b: &[f32], acc: &mut [f32], lo: usize, hi: usize, m: usize, n: usize) {
    let mut r = lo;
    while r + 1 < hi {
        let a0 = &a[r * m..(r + 1) * m];
        let a1 = &a[(r + 1) * m..(r + 2) * m];
        let b0 = &b[r * n..(r + 1) * n];
        let b1 = &b[(r + 1) * n..(r + 2) * n];
        for i in 0..m {
            let (x0, x1) = (a0[i], a1[i]);
            if x0 == 0.0 && x1 == 0.0 {
                continue;
            }
            let acc_row = &mut acc[i * n..(i + 1) * n];
            for ((o, &v0), &v1) in acc_row.iter_mut().zip(b0).zip(b1) {
                *o += x0 * v0 + x1 * v1;
            }
        }
        r += 2;
    }
    if r < hi {
        let a0 = &a[r * m..(r + 1) * m];
        let b0 = &b[r * n..(r + 1) * n];
        for i in 0..m {
            let x0 = a0[i];
            if x0 == 0.0 {
                continue;
            }
            let acc_row = &mut acc[i * n..(i + 1) * n];
            for (o, &v0) in acc_row.iter_mut().zip(b0) {
                *o += x0 * v0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(rows: usize, cols: usize, vals: &[f32]) -> Matrix {
        Matrix::from_vec(rows, cols, vals.to_vec())
    }

    #[test]
    fn matmul_small_known() {
        let a = m(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = m(2, 2, &[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_rectangular() {
        let a = m(1, 3, &[1.0, 2.0, 3.0]);
        let b = m(3, 2, &[1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![4.0, 5.0]);
    }

    #[test]
    fn matmul_matches_naive_across_tile_edges() {
        // shapes straddling the MR/NR tile boundaries in every direction
        let mut rng = crate::util::Rng::new(9);
        for &(rows, k, n) in
            &[(1usize, 1usize, 1usize), (4, 4, 8), (5, 3, 9), (7, 17, 23), (8, 32, 8), (13, 5, 1)]
        {
            let a = Matrix::from_fn(rows, k, |_, _| rng.next_normal());
            let b = Matrix::from_fn(k, n, |_, _| rng.next_normal());
            let got = a.matmul(&b);
            for i in 0..rows {
                for j in 0..n {
                    let want: f32 = (0..k).map(|x| a.get(i, x) * b.get(x, j)).sum();
                    assert!(
                        (got.get(i, j) - want).abs() < 1e-4,
                        "({rows}x{k}@{k}x{n}) [{i},{j}]: {} vs {want}",
                        got.get(i, j)
                    );
                }
            }
        }
    }

    #[test]
    fn matmul_into_overwrites_scratch_contents() {
        let a = m(2, 2, &[1.0, 0.0, 0.0, 1.0]);
        let b = m(2, 2, &[5.0, 6.0, 7.0, 8.0]);
        let mut out = Matrix::from_vec(2, 2, vec![99.0; 4]);
        a.matmul_into(&b, &mut out);
        assert_eq!(out.data, b.data);
    }

    #[test]
    fn matmul_sparse_probe_path_matches_naive() {
        // mostly-zero A routes to the zero-skip kernel; values must match
        // the naive triple loop regardless of the path taken
        let mut rng = crate::util::Rng::new(4);
        let a = Matrix::from_fn(
            40,
            40,
            |i, j| if (i + j) % 16 == 0 { rng.next_normal() } else { 0.0 },
        );
        assert!(a.mostly_zero());
        let b = Matrix::from_fn(40, 6, |_, _| rng.next_normal());
        let got = a.matmul(&b);
        for i in 0..40 {
            for j in 0..6 {
                let want: f32 = (0..40).map(|x| a.get(i, x) * b.get(x, j)).sum();
                assert!((got.get(i, j) - want).abs() < 1e-4, "[{i},{j}]");
            }
        }
    }

    #[test]
    fn matmul_range_blocks_match_full_call_bitwise() {
        let mut rng = crate::util::Rng::new(11);
        // dense operand (register-tiled path) and a mostly-zero operand
        // (zero-skip path): every row's bits must be split-invariant
        let dense = Matrix::from_fn(13, 17, |_, _| rng.next_normal());
        let sparse = Matrix::from_fn(
            40,
            17,
            |i, j| if (i + j) % 16 == 0 { rng.next_normal() } else { 0.0 },
        );
        for a in [&dense, &sparse] {
            let b = Matrix::from_fn(17, 9, |_, _| rng.next_normal());
            let mut full = Matrix::zeros(a.rows, 9);
            a.matmul_into(&b, &mut full);
            for split in [0usize, 1, 3, a.rows / 2, a.rows] {
                let mut blocked = Matrix::from_vec(a.rows, 9, vec![f32::NAN; a.rows * 9]);
                a.matmul_range_into(&b, &mut blocked, 0, split);
                a.matmul_range_into(&b, &mut blocked, split, a.rows);
                assert_eq!(full.data, blocked.data, "split at {split} (rows {})", a.rows);
            }
        }
    }

    #[test]
    fn matmul_nt_range_blocks_match_full_call_bitwise() {
        let mut rng = crate::util::Rng::new(12);
        let a = Matrix::from_fn(11, 6, |_, _| rng.next_normal());
        let b = Matrix::from_fn(7, 6, |_, _| rng.next_normal());
        let mut full = Matrix::zeros(11, 7);
        a.matmul_nt_into(&b, &mut full);
        for split in [0usize, 1, 5, 11] {
            let mut blocked = Matrix::from_vec(11, 7, vec![f32::NAN; 77]);
            a.matmul_nt_range_into(&b, &mut blocked, 0, split);
            a.matmul_nt_range_into(&b, &mut blocked, split, 11);
            assert_eq!(full.data, blocked.data, "split at {split}");
        }
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let mut rng = crate::util::Rng::new(2);
        for &(rows, k, n) in &[(1usize, 1usize, 1usize), (5, 9, 7), (6, 4, 12), (3, 13, 2)] {
            let a = Matrix::from_fn(rows, k, |_, _| rng.next_normal());
            let b = Matrix::from_fn(n, k, |_, _| rng.next_normal());
            let want = a.matmul(&b.transpose());
            let got = a.matmul_nt(&b);
            assert_eq!(got.shape(), (rows, n));
            for (x, y) in got.data.iter().zip(&want.data) {
                assert!((x - y).abs() < 1e-4, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let mut rng = crate::util::Rng::new(1);
        let a = Matrix::from_fn(7, 5, |_, _| rng.next_normal());
        let b = Matrix::from_fn(7, 3, |_, _| rng.next_normal());
        let want = a.transpose().matmul(&b);
        let got = a.t_matmul(&b);
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn t_matmul_spans_multiple_slabs() {
        // k > T_SLAB exercises the slab-partial reduction
        let k = T_SLAB * 2 + 17;
        let mut rng = crate::util::Rng::new(3);
        let a = Matrix::from_fn(k, 4, |_, _| rng.next_normal());
        let b = Matrix::from_fn(k, 3, |_, _| rng.next_normal());
        let want = a.transpose().matmul(&b);
        let got = a.t_matmul(&b);
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn kernels_are_thread_count_invariant() {
        // identical bits no matter the intra-op thread budget
        let mut rng = crate::util::Rng::new(5);
        let a = Matrix::from_fn(37, T_SLAB + 9, |_, _| rng.next_normal());
        let b = Matrix::from_fn(T_SLAB + 9, 11, |_, _| rng.next_normal());
        let nt_b = Matrix::from_fn(23, T_SLAB + 9, |_, _| rng.next_normal());
        let tall = Matrix::from_fn(T_SLAB + 9, 37, |_, _| rng.next_normal());
        let base = crate::util::parallel::with_thread_limit(1, || {
            (a.matmul(&b), a.matmul_nt(&nt_b), tall.t_matmul(&b))
        });
        for threads in [2usize, 3, 8] {
            let got = crate::util::parallel::with_thread_limit(threads, || {
                (a.matmul(&b), a.matmul_nt(&nt_b), tall.t_matmul(&b))
            });
            assert_eq!(base.0.data, got.0.data, "matmul at {threads} threads");
            assert_eq!(base.1.data, got.1.data, "matmul_nt at {threads} threads");
            assert_eq!(base.2.data, got.2.data, "t_matmul at {threads} threads");
        }
    }

    #[test]
    fn empty_dims_are_well_defined() {
        let a = Matrix::zeros(0, 3);
        let b = Matrix::zeros(3, 4);
        assert_eq!(a.matmul(&b).shape(), (0, 4));
        let c = Matrix::zeros(2, 0);
        let d = Matrix::zeros(0, 5);
        assert_eq!(c.matmul(&d).data, vec![0.0; 10]);
        assert_eq!(c.matmul_nt(&Matrix::zeros(4, 0)).shape(), (2, 4));
        assert_eq!(d.t_matmul(&Matrix::zeros(0, 2)).shape(), (5, 2));
    }

    #[test]
    fn transpose_round_trip() {
        let a = m(2, 3, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn relu_clamps_negatives() {
        let mut a = m(1, 4, &[-1.0, 0.0, 2.0, -3.0]);
        a.relu();
        assert_eq!(a.data, vec![0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn bias_broadcast() {
        let mut a = Matrix::zeros(2, 3);
        a.add_row_broadcast(&[1.0, 2.0, 3.0]);
        assert_eq!(a.data, vec![1., 2., 3., 1., 2., 3.]);
    }

    #[test]
    fn argmax_rows_picks_first_max() {
        let a = m(2, 3, &[0.1, 0.9, 0.9, 1.0, -1.0, 0.5]);
        assert_eq!(a.argmax_rows(), vec![1, 0]);
    }

    #[test]
    #[should_panic(expected = "matmul")]
    fn matmul_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        a.matmul(&b);
    }

    #[test]
    #[should_panic(expected = "matmul_nt")]
    fn matmul_nt_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 4);
        a.matmul_nt(&b);
    }
}
