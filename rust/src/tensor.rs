//! Minimal dense f32 matrix used by the native engine and the PJRT
//! marshalling layer.  Row-major, rayon-parallel matmul.
//!
//! Deliberately tiny: the heavy lifting on the artifact path happens in
//! XLA; the native engine's hot loops are the sparse aggregations in
//! `engine::native`, which operate on raw slices.

use crate::util::parallel;

/// Row-major dense f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length {} != {rows}x{cols}", data.len());
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// self @ other, rayon-parallel over output rows, k-inner loop kept
    /// contiguous over `other` rows for cache friendliness.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul {}x{} @ {}x{}", self.rows, self.cols, other.rows, other.cols);
        let mut out = Matrix::zeros(self.rows, other.cols);
        let oc = other.cols;
        parallel::par_chunks_mut(&mut out.data, oc, |i, out_row| {
            let a_row = self.row(i);
            for (k, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = &other.data[k * oc..(k + 1) * oc];
                for (o, &b) in out_row.iter_mut().zip(b_row) {
                    *o += a * b;
                }
            }
        });
        out
    }

    /// selfᵀ @ other without materializing the transpose.
    pub fn t_matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "t_matmul {}x{} @ {}x{}", self.rows, self.cols, other.rows, other.cols);
        let (k, m, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        // Accumulate thread-local partials over row slabs of k, then reduce.
        let nt = parallel::effective_threads().min(k.max(1));
        let partials: Vec<Matrix> = parallel::par_map(nt, |t| {
            let mut acc = Matrix::zeros(m, n);
            let lo = k * t / nt;
            let hi = k * (t + 1) / nt;
            for r in lo..hi {
                let a_row = self.row(r);
                let b_row = other.row(r);
                for (i, &a) in a_row.iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    let acc_row = acc.row_mut(i);
                    for (o, &b) in acc_row.iter_mut().zip(b_row) {
                        *o += a * b;
                    }
                }
            }
            acc
        });
        for p in partials {
            for (o, v) in out.data.iter_mut().zip(p.data) {
                *o += v;
            }
        }
        out
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// Add a row vector to every row (bias broadcast).
    pub fn add_row_broadcast(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        for i in 0..self.rows {
            for (a, &b) in self.row_mut(i).iter_mut().zip(bias) {
                *a += b;
            }
        }
    }

    pub fn relu(&mut self) {
        for a in self.data.iter_mut() {
            if *a < 0.0 {
                *a = 0.0;
            }
        }
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Row-wise argmax (predictions from logits).
    pub fn argmax_rows(&self) -> Vec<u32> {
        (0..self.rows)
            .map(|i| {
                let row = self.row(i);
                let mut best = 0usize;
                for (j, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = j;
                    }
                }
                best as u32
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(rows: usize, cols: usize, vals: &[f32]) -> Matrix {
        Matrix::from_vec(rows, cols, vals.to_vec())
    }

    #[test]
    fn matmul_small_known() {
        let a = m(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = m(2, 2, &[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_rectangular() {
        let a = m(1, 3, &[1.0, 2.0, 3.0]);
        let b = m(3, 2, &[1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        assert_eq!(a.matmul(&b).data, vec![4.0, 5.0]);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let mut rng = crate::util::Rng::new(1);
        let a = Matrix::from_fn(7, 5, |_, _| rng.next_normal());
        let b = Matrix::from_fn(7, 3, |_, _| rng.next_normal());
        let want = a.transpose().matmul(&b);
        let got = a.t_matmul(&b);
        for (x, y) in got.data.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn transpose_round_trip() {
        let a = m(2, 3, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn relu_clamps_negatives() {
        let mut a = m(1, 4, &[-1.0, 0.0, 2.0, -3.0]);
        a.relu();
        assert_eq!(a.data, vec![0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn bias_broadcast() {
        let mut a = Matrix::zeros(2, 3);
        a.add_row_broadcast(&[1.0, 2.0, 3.0]);
        assert_eq!(a.data, vec![1., 2., 3., 1., 2., 3.]);
    }

    #[test]
    fn argmax_rows_picks_first_max() {
        let a = m(2, 3, &[0.1, 0.9, 0.9, 1.0, -1.0, 0.5]);
        assert_eq!(a.argmax_rows(), vec![1, 0]);
    }

    #[test]
    #[should_panic(expected = "matmul")]
    fn matmul_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        a.matmul(&b);
    }
}
