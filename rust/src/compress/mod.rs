//! Compression channel (paper §III-A, Definition 1, Appendix A) and the
//! compression-rate schedulers that make VARCO "variable" (§IV).
//!
//! The mechanism of record is `RandomSubsetCompressor`: keep
//! ``m = ceil(len / r)`` elements of the flattened payload at positions
//! drawn from a **shared key** (both endpoints derive the same index set,
//! nothing but the kept values travels); the decoder scatters them and
//! zeros the rest.  `TopK` and `Quantize` are baselines for the ablation
//! benches.

pub mod error_feedback;
pub mod quantize;
pub mod scheduler;
pub mod subset;
pub mod topk;

pub use error_feedback::ErrorFeedback;
pub use scheduler::{CommMode, Scheduler};
pub use subset::RandomSubsetCompressor;

use crate::Result;

/// A compressed payload on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct Payload {
    /// original (uncompressed) length
    pub n: usize,
    /// kept / encoded values
    pub values: Vec<f32>,
    /// explicit indices (only for mechanisms that must transmit them)
    pub indices: Option<Vec<u32>>,
    /// shared key the endpoints use to derive implicit indices
    pub key: u64,
    /// extra scalar side-channel (e.g. quantizer min/max)
    pub side: Vec<f32>,
    /// wire cost override in float-equivalents, for mechanisms whose
    /// simulated representation differs from what travels (e.g. the
    /// quantizer keeps codes as f32 but ships b-bit words)
    pub wire_override: Option<usize>,
}

impl Payload {
    /// Floats-equivalent on the wire: what Figure 5's x-axis counts.
    /// Indices cost one 4-byte word each, i.e. one float-equivalent.
    pub fn wire_floats(&self) -> usize {
        if let Some(w) = self.wire_override {
            return w;
        }
        self.values.len()
            + self.indices.as_ref().map_or(0, |i| i.len())
            + self.side.len()
    }
}

/// A lossy compression mechanism per Definition 1.
pub trait Compressor: Send + Sync {
    fn name(&self) -> &'static str;

    /// Compress `x` at rate `rate >= 1`; `key` is the shared random key.
    fn compress(&self, x: &[f32], rate: f32, key: u64) -> Payload;

    /// Reconstruct into `out` (length `payload.n`), zeros where dropped.
    fn decompress(&self, payload: &Payload, out: &mut [f32]);
}

/// Number of kept elements for a payload of `n` at rate `r` (>= 1 kept).
pub fn kept_count(n: usize, rate: f32) -> usize {
    assert!(rate >= 1.0, "rate {rate} < 1");
    ((n as f64 / rate as f64).ceil() as usize).clamp(1.min(n), n)
}

/// Look up a compressor by config name.
pub fn by_name(name: &str) -> Result<Box<dyn Compressor>> {
    match name {
        "subset" | "random-subset" => Ok(Box::new(subset::RandomSubsetCompressor)),
        "topk" => Ok(Box::new(topk::TopKCompressor)),
        "quantize" => Ok(Box::new(quantize::QuantizeCompressor)),
        _ => anyhow::bail!("unknown compressor {name}; known: subset, topk, quantize"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kept_count_ceil_and_bounds() {
        assert_eq!(kept_count(100, 1.0), 100);
        assert_eq!(kept_count(100, 3.0), 34);
        assert_eq!(kept_count(100, 128.0), 1);
        assert_eq!(kept_count(5, 2.0), 3);
        assert_eq!(kept_count(0, 2.0), 0);
    }

    #[test]
    #[should_panic(expected = "rate")]
    fn kept_count_rejects_sub_one_rate() {
        kept_count(10, 0.5);
    }

    #[test]
    fn by_name_resolves() {
        for n in ["subset", "topk", "quantize"] {
            assert!(by_name(n).is_ok());
        }
        assert!(by_name("zip").is_err());
    }

    #[test]
    fn wire_floats_accounts_indices_and_side() {
        let mut p = Payload {
            n: 10,
            values: vec![1.0; 4],
            indices: Some(vec![0, 1, 2, 3]),
            key: 0,
            side: vec![0.5, 2.0],
            wire_override: None,
        };
        assert_eq!(p.wire_floats(), 10);
        p.wire_override = Some(3);
        assert_eq!(p.wire_floats(), 3);
    }
}
