//! Compression channel (paper §III-A, Definition 1, Appendix A), the wire
//! codec that serializes it byte-exactly, and the rate controllers that
//! make VARCO "variable" (§IV).
//!
//! The mechanism of record is `RandomSubsetCompressor`: keep
//! ``m = ceil(len / r)`` elements of the flattened payload at positions
//! drawn from a **shared key** (both endpoints derive the same index set,
//! nothing but the kept values travels); the decoder scatters them and
//! zeros the rest.  `TopK` and `Quantize` are baselines for the ablation
//! benches.
//!
//! Every payload carries a [`Codec`] describing its serialized form;
//! [`Payload::wire_bytes`] is the exact length `Payload::encode` produces
//! (see [`wire`]), and the fabric's ledger accounts those bytes.  Rates
//! are chosen either open-loop by a [`Scheduler`] or closed-loop by a
//! [`controller::BudgetController`] that spends an explicit byte budget.

pub mod controller;
pub mod error_feedback;
pub mod quantize;
pub mod scheduler;
pub mod subset;
pub mod topk;
pub mod wire;

pub use controller::{
    BudgetController, ChannelKind, Feedback, LayerFeedback, LinkAwareBudgetController, LinkCell,
    OpenLoopController, RateController,
};
pub use error_feedback::{plan_channel, ErrorFeedback};
pub use scheduler::{CommMode, RateAlloc, Scheduler};
pub use subset::RandomSubsetCompressor;

use crate::Result;

/// How a payload's body is serialized on the wire (see [`wire`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Codec {
    /// values only; kept positions are re-derived from the shared key
    /// (the paper's subset mechanism, and the dense rate-1 fast path)
    Keyed,
    /// explicit ascending u32 indices, delta+varint coded (top-k)
    Indexed,
    /// b-bit uniform quantizer codes, bit-packed LSB-first
    Quantized { bits: u8 },
}

/// A compressed payload on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct Payload {
    /// original (uncompressed) length
    pub n: usize,
    /// kept / encoded values (quantizer codes stay f32 in simulation;
    /// the codec bit-packs them on the wire)
    pub values: Vec<f32>,
    /// explicit indices (only for mechanisms that must transmit them)
    pub indices: Option<Vec<u32>>,
    /// shared key the endpoints use to derive implicit indices
    pub key: u64,
    /// extra scalar side-channel (e.g. quantizer min/max)
    pub side: Vec<f32>,
    /// serialized representation (drives `encode` / `wire_bytes`)
    pub codec: Codec,
}

impl Payload {
    /// Float-equivalents on the wire — the historical Figure 5 x-axis,
    /// now a *derived view* of the exact byte count.
    pub fn wire_floats(&self) -> usize {
        self.wire_bytes().div_ceil(4)
    }

    /// The canonical "this message was lost" payload: shape (`n`) and key
    /// preserved, no values, no side channel.  Every codec's decoder
    /// reconstructs exact zeros from it — the compression mechanism's
    /// natural missing-value semantics.  The fabric's drop injection
    /// substitutes this AFTER the wire cost of the real payload was
    /// charged (a dropped message still paid for its bytes); zeroing the
    /// raw values instead would be wrong for the quantizer, whose zero
    /// codes decode to the side-channel `min`, not zero.
    pub fn dropped(n: usize, key: u64) -> Payload {
        Payload { n, values: vec![], indices: None, key, side: vec![], codec: Codec::Keyed }
    }

    /// Is this the [`Payload::dropped`] tombstone?  (A genuine compressed
    /// payload of a non-empty message always keeps at least one value.)
    pub fn is_dropped(&self) -> bool {
        self.n > 0 && self.values.is_empty() && self.indices.is_none() && self.side.is_empty()
    }
}

/// A lossy compression mechanism per Definition 1.
pub trait Compressor: Send + Sync {
    fn name(&self) -> &'static str;

    /// Compress `x` at rate `rate >= 1`; `key` is the shared random key.
    fn compress(&self, x: &[f32], rate: f32, key: u64) -> Payload;

    /// Reconstruct into `out` (length `payload.n`), zeros where dropped.
    fn decompress(&self, payload: &Payload, out: &mut [f32]);

    /// `(||x − x̂||², ||x||²)` for a payload just produced from `x` — the
    /// channel's squared error and the signal mass it acted on, fed back
    /// to closed-loop rate controllers.  One method so both sums cost a
    /// single pass; the default reconstructs and diffs, mechanisms with
    /// cheaper identities override it.
    fn channel_error(&self, x: &[f32], payload: &Payload) -> (f32, f32) {
        let mut xhat = vec![0.0f32; payload.n];
        self.decompress(payload, &mut xhat);
        let (mut err, mut sig) = (0.0f32, 0.0f32);
        for (&a, &b) in x.iter().zip(&xhat) {
            err += (a - b) * (a - b);
            sig += a * a;
        }
        (err, sig)
    }
}

/// Number of kept elements for a payload of `n` at rate `r` (>= 1 kept).
pub fn kept_count(n: usize, rate: f32) -> usize {
    assert!(rate >= 1.0, "rate {rate} < 1");
    ((n as f64 / rate as f64).ceil() as usize).clamp(1.min(n), n)
}

/// Look up a compressor by config name.
pub fn by_name(name: &str) -> Result<Box<dyn Compressor>> {
    match name {
        "subset" | "random-subset" => Ok(Box::new(subset::RandomSubsetCompressor)),
        "topk" => Ok(Box::new(topk::TopKCompressor)),
        "quantize" => Ok(Box::new(quantize::QuantizeCompressor)),
        _ => anyhow::bail!("unknown compressor {name}; known: subset, topk, quantize"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kept_count_ceil_and_bounds() {
        assert_eq!(kept_count(100, 1.0), 100);
        assert_eq!(kept_count(100, 3.0), 34);
        assert_eq!(kept_count(100, 128.0), 1);
        assert_eq!(kept_count(5, 2.0), 3);
        assert_eq!(kept_count(0, 2.0), 0);
    }

    #[test]
    #[should_panic(expected = "rate")]
    fn kept_count_rejects_sub_one_rate() {
        kept_count(10, 0.5);
    }

    #[test]
    fn by_name_resolves() {
        for n in ["subset", "topk", "quantize"] {
            assert!(by_name(n).is_ok());
        }
        assert!(by_name("zip").is_err());
    }

    #[test]
    fn wire_floats_is_derived_from_bytes() {
        let p = Payload {
            n: 10,
            values: vec![1.0; 4],
            indices: Some(vec![0, 1, 2, 3]),
            key: 0,
            side: vec![0.5, 2.0],
            codec: Codec::Indexed,
        };
        assert_eq!(p.wire_floats(), p.wire_bytes().div_ceil(4));
        assert_eq!(p.wire_bytes(), p.encode().len());
    }

    #[test]
    fn default_channel_error_reconstructs_and_diffs() {
        // a mechanism without an override gets the decompress-and-diff
        // default; for a lossless channel the error must be exactly zero
        struct Identity;
        impl Compressor for Identity {
            fn name(&self) -> &'static str {
                "identity"
            }
            fn compress(&self, x: &[f32], _rate: f32, key: u64) -> Payload {
                Payload {
                    n: x.len(),
                    values: x.to_vec(),
                    indices: None,
                    key,
                    side: vec![],
                    codec: Codec::Keyed,
                }
            }
            fn decompress(&self, payload: &Payload, out: &mut [f32]) {
                out.copy_from_slice(&payload.values);
            }
        }
        let x: Vec<f32> = (0..64).map(|i| (i as f32).sin()).collect();
        let p = Identity.compress(&x, 8.0, 3);
        let sig: f32 = x.iter().map(|v| v * v).sum();
        assert_eq!(Identity.channel_error(&x, &p), (0.0, sig));
        // and the overrides agree with the default on a lossy channel
        let c = by_name("quantize").unwrap();
        let q = c.compress(&x, 8.0, 3);
        let mut xhat = vec![0.0; x.len()];
        c.decompress(&q, &mut xhat);
        let want: f32 = x.iter().zip(&xhat).map(|(a, b)| (a - b) * (a - b)).sum();
        let (err, got_sig) = c.channel_error(&x, &q);
        assert!((err - want).abs() < 1e-5 * (1.0 + want));
        assert!((got_sig - sig).abs() < 1e-3 * (1.0 + sig));
    }
}
