//! Byte-exact wire format for compressed payloads.
//!
//! Every message the fabric carries is accountable in *serialized bytes*,
//! not float-equivalents: `encode` produces the exact buffer that would
//! travel, `decode` reconstructs the payload, and `Payload::wire_bytes`
//! computes the buffer length analytically without allocating (pinned to
//! `encode().len()` by `tests/properties.rs`).
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! u32    body length (everything after this prefix)
//! u8     codec tag (0 = Keyed, 1 = Indexed, 2 = Quantized)
//! [u8    bits]                 Quantized only
//! varint n                     original (uncompressed) element count
//! u64    key                   shared compression key
//! varint side_len; side_len × f32
//! varint m                     encoded value count
//! body:
//!   Keyed      m × f32 values (indices are re-derived from the key)
//!   Indexed    m delta-varints (first index, then successive gaps),
//!              then m × f32 values
//!   Quantized  ceil(m·bits / 8) bytes of LSB-first bit-packed codes
//! ```
//!
//! Varints are LEB128 (7 data bits per byte, high bit = continuation).
//! Top-k indices are strictly ascending, so the gap sequence is
//! non-negative and small — the delta+varint coding beats the old flat
//! 4-bytes-per-index accounting at every rate.

use super::{Codec, Payload};
use crate::Result;

// ---------------- varint primitives ----------------

/// Encoded length of a LEB128 varint.
pub fn varint_len(mut v: u64) -> usize {
    let mut n = 1;
    while v >= 0x80 {
        v >>= 7;
        n += 1;
    }
    n
}

/// Analytic wire size of a `Codec::Keyed` payload carrying `m` of `n`
/// message elements plus `side` side-channel floats, without building the
/// payload.  Pinned against [`Payload::wire_bytes`] by test; route
/// planning (1.5D replica scoring) uses it to estimate per-link load
/// before any payload exists.
pub fn keyed_wire_bytes(n: usize, m: usize, side: usize) -> usize {
    4 + 1 + varint_len(n as u64) + 8 + varint_len(side as u64) + 4 * side + varint_len(m as u64) + 4 * m
}

fn put_varint(buf: &mut Vec<u8>, mut v: u64) {
    while v >= 0x80 {
        buf.push((v as u8 & 0x7F) | 0x80);
        v >>= 7;
    }
    buf.push(v as u8);
}

fn get_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let b = *buf
            .get(*pos)
            .ok_or_else(|| anyhow::anyhow!("wire: truncated varint at byte {}", *pos))?;
        *pos += 1;
        let chunk = u64::from(b & 0x7F);
        // reject overlong encodings outright: a chunk whose bits would be
        // shifted off the top must not silently truncate to a wrong value
        anyhow::ensure!(
            shift < 64 && (chunk << shift) >> shift == chunk,
            "wire: varint overflows u64"
        );
        v |= chunk << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
    }
}

fn put_f32(buf: &mut Vec<u8>, x: f32) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn get_f32(buf: &[u8], pos: &mut usize) -> Result<f32> {
    let bytes: [u8; 4] = buf
        .get(*pos..*pos + 4)
        .ok_or_else(|| anyhow::anyhow!("wire: truncated f32 at byte {}", *pos))?
        .try_into()
        .unwrap();
    *pos += 4;
    Ok(f32::from_le_bytes(bytes))
}

// ---------------- bit packing (quantizer codes) ----------------

/// Largest code representable in a `bits`-wide field.  Codes are produced
/// by `round((v - lo) * scale)` and stay f32 in simulation; at bits = 32
/// the f32 rounding of `levels` can reach exactly 2^32, so packing clamps
/// into the field — the clamped code converts back to the identical f32
/// (the nearest representable float), keeping the round-trip exact.
fn field_max(bits: u8) -> u64 {
    if bits >= 64 {
        u64::MAX
    } else {
        (1u64 << bits) - 1
    }
}

fn pack_codes(buf: &mut Vec<u8>, codes: &[f32], bits: u8) {
    let mut acc = 0u64;
    let mut used = 0u32;
    for &c in codes {
        let code = (c as u64).min(field_max(bits));
        acc |= code << used;
        used += u32::from(bits);
        while used >= 8 {
            buf.push(acc as u8);
            acc >>= 8;
            used -= 8;
        }
    }
    if used > 0 {
        buf.push(acc as u8);
    }
}

fn unpack_codes(buf: &[u8], pos: &mut usize, m: usize, bits: u8) -> Result<Vec<f32>> {
    let nbytes = (m * bits as usize).div_ceil(8);
    let src = buf
        .get(*pos..*pos + nbytes)
        .ok_or_else(|| anyhow::anyhow!("wire: truncated code block at byte {}", *pos))?;
    *pos += nbytes;
    let mut out = Vec::with_capacity(m);
    let mut acc = 0u64;
    let mut used = 0u32;
    let mut next = 0usize;
    for _ in 0..m {
        while used < u32::from(bits) {
            acc |= u64::from(src[next]) << used;
            next += 1;
            used += 8;
        }
        out.push((acc & field_max(bits)) as f32);
        acc >>= u32::from(bits);
        used -= u32::from(bits);
    }
    Ok(out)
}

// ---------------- codec tags ----------------

fn codec_tag(codec: Codec) -> u8 {
    match codec {
        Codec::Keyed => 0,
        Codec::Indexed => 1,
        Codec::Quantized { .. } => 2,
    }
}

impl Payload {
    /// Serialize to the length-prefixed wire buffer.
    pub fn encode(&self) -> Vec<u8> {
        // upper-bound capacity without pre-walking index deltas (the exact
        // length needs an O(m) delta scan for Indexed; the prefix is
        // patched in after the single serialization pass)
        let cap = 24
            + 4 * self.side.len()
            + 4 * self.values.len()
            + self.indices.as_ref().map_or(0, |i| 5 * i.len());
        let mut buf = Vec::with_capacity(cap);
        buf.extend_from_slice(&[0u8; 4]); // length prefix, patched below
        buf.push(codec_tag(self.codec));
        if let Codec::Quantized { bits } = self.codec {
            buf.push(bits);
        }
        put_varint(&mut buf, self.n as u64);
        buf.extend_from_slice(&self.key.to_le_bytes());
        put_varint(&mut buf, self.side.len() as u64);
        for &s in &self.side {
            put_f32(&mut buf, s);
        }
        put_varint(&mut buf, self.values.len() as u64);
        match self.codec {
            Codec::Keyed => {
                for &v in &self.values {
                    put_f32(&mut buf, v);
                }
            }
            Codec::Indexed => {
                let idx = self.indices.as_ref().expect("indexed payload carries indices");
                let mut prev = 0u32;
                for (k, &i) in idx.iter().enumerate() {
                    let delta = if k == 0 { i } else { i - prev };
                    put_varint(&mut buf, u64::from(delta));
                    prev = i;
                }
                for &v in &self.values {
                    put_f32(&mut buf, v);
                }
            }
            Codec::Quantized { bits } => pack_codes(&mut buf, &self.values, bits),
        }
        let body = (buf.len() - 4) as u32;
        buf[0..4].copy_from_slice(&body.to_le_bytes());
        debug_assert_eq!(buf.len(), self.wire_bytes(), "wire_bytes disagrees with encode");
        buf
    }

    /// Parse a buffer produced by [`Payload::encode`].
    pub fn decode(buf: &[u8]) -> Result<Payload> {
        anyhow::ensure!(buf.len() >= 4, "wire: missing length prefix");
        let body_len = u32::from_le_bytes(buf[0..4].try_into().unwrap()) as usize;
        anyhow::ensure!(
            buf.len() == body_len + 4,
            "wire: length prefix {} != body {}",
            body_len,
            buf.len() - 4
        );
        let mut pos = 4usize;
        let tag = buf[pos];
        pos += 1;
        let codec = match tag {
            0 => Codec::Keyed,
            1 => Codec::Indexed,
            2 => {
                let bits = *buf
                    .get(pos)
                    .ok_or_else(|| anyhow::anyhow!("wire: truncated quantizer header"))?;
                pos += 1;
                anyhow::ensure!((1..=32).contains(&bits), "wire: bad bit width {bits}");
                Codec::Quantized { bits }
            }
            t => anyhow::bail!("wire: unknown codec tag {t}"),
        };
        let n = get_varint(buf, &mut pos)? as usize;
        let key_bytes: [u8; 8] = buf
            .get(pos..pos + 8)
            .ok_or_else(|| anyhow::anyhow!("wire: truncated key"))?
            .try_into()
            .unwrap();
        pos += 8;
        let key = u64::from_le_bytes(key_bytes);
        // every count is validated against the bytes actually present
        // BEFORE any allocation, so a corrupt buffer yields Err instead of
        // a huge Vec::with_capacity (or an arithmetic overflow)
        let side_len = get_varint(buf, &mut pos)? as usize;
        anyhow::ensure!(
            side_len <= (buf.len() - pos) / 4,
            "wire: side length {side_len} exceeds remaining buffer"
        );
        let mut side = Vec::with_capacity(side_len);
        for _ in 0..side_len {
            side.push(get_f32(buf, &mut pos)?);
        }
        let m = get_varint(buf, &mut pos)? as usize;
        let remaining = buf.len() - pos;
        let fits = match codec {
            // m f32 values (Indexed additionally carries >= 1 byte/index)
            Codec::Keyed => m <= remaining / 4,
            Codec::Indexed => m <= remaining / 5,
            Codec::Quantized { bits } => {
                m <= remaining.saturating_mul(8) / usize::from(bits.max(1))
            }
        };
        anyhow::ensure!(fits, "wire: value count {m} exceeds remaining buffer ({remaining} B)");
        let (values, indices) = match codec {
            Codec::Keyed => {
                let mut values = Vec::with_capacity(m);
                for _ in 0..m {
                    values.push(get_f32(buf, &mut pos)?);
                }
                (values, None)
            }
            Codec::Indexed => {
                let mut idx = Vec::with_capacity(m);
                let mut prev = 0u64;
                for k in 0..m {
                    let delta = get_varint(buf, &mut pos)?;
                    let i = if k == 0 {
                        delta
                    } else {
                        prev.checked_add(delta)
                            .ok_or_else(|| anyhow::anyhow!("wire: index delta overflow"))?
                    };
                    anyhow::ensure!(i < n as u64, "wire: index {i} out of range {n}");
                    idx.push(i as u32);
                    prev = i;
                }
                let mut values = Vec::with_capacity(m);
                for _ in 0..m {
                    values.push(get_f32(buf, &mut pos)?);
                }
                (values, Some(idx))
            }
            Codec::Quantized { bits } => (unpack_codes(buf, &mut pos, m, bits)?, None),
        };
        anyhow::ensure!(pos == buf.len(), "wire: {} trailing bytes", buf.len() - pos);
        Ok(Payload { n, values, indices, key, side, codec })
    }

    /// Exact encoded length in bytes, computed without serializing.
    pub fn wire_bytes(&self) -> usize {
        let m = self.values.len();
        let mut total = 4 // length prefix
            + 1 // codec tag
            + varint_len(self.n as u64)
            + 8 // key
            + varint_len(self.side.len() as u64)
            + 4 * self.side.len()
            + varint_len(m as u64);
        match self.codec {
            Codec::Keyed => total += 4 * m,
            Codec::Indexed => {
                let idx = self.indices.as_ref().expect("indexed payload carries indices");
                let mut prev = 0u32;
                for (k, &i) in idx.iter().enumerate() {
                    let delta = if k == 0 { i } else { i - prev };
                    total += varint_len(u64::from(delta));
                    prev = i;
                }
                total += 4 * m;
            }
            Codec::Quantized { bits } => {
                total += 1 + (m * bits as usize).div_ceil(8);
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Codec, Payload};
    use super::*;

    fn keyed(n: usize, values: Vec<f32>) -> Payload {
        Payload { n, values, indices: None, key: 0xDEAD_BEEF, side: vec![], codec: Codec::Keyed }
    }

    #[test]
    fn varint_roundtrip_and_lengths() {
        for v in [0u64, 1, 127, 128, 300, 16_383, 16_384, u32::MAX as u64, u64::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            assert_eq!(buf.len(), varint_len(v), "len for {v}");
            let mut pos = 0;
            assert_eq!(get_varint(&buf, &mut pos).unwrap(), v);
            assert_eq!(pos, buf.len());
        }
    }

    #[test]
    fn analytic_keyed_size_matches_real_payloads() {
        for (n, side) in [(1usize, 0usize), (7, 0), (300, 3), (70_000, 1)] {
            let mut p = keyed(n, vec![0.5; n]);
            p.side = vec![1.0; side];
            assert_eq!(p.wire_bytes(), keyed_wire_bytes(n, n, side), "n={n} side={side}");
        }
    }

    #[test]
    fn varint_rejects_overlong_encodings() {
        // 10th byte carries bit 63 only: a chunk of 2 would shift off the
        // top and must be rejected, not truncated to a wrong value
        let overlong = [0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x02];
        let mut pos = 0;
        assert!(get_varint(&overlong, &mut pos).is_err());
        // an 11-byte varint overflows outright
        let too_long = [0x80u8; 11];
        let mut pos = 0;
        assert!(get_varint(&too_long, &mut pos).is_err());
    }

    #[test]
    fn keyed_roundtrip_exact() {
        let p = keyed(10, vec![1.5, -2.25, 0.0, f32::MIN_POSITIVE]);
        let buf = p.encode();
        assert_eq!(buf.len(), p.wire_bytes());
        assert_eq!(Payload::decode(&buf).unwrap(), p);
    }

    #[test]
    fn empty_payload_roundtrip() {
        let p = keyed(0, vec![]);
        let buf = p.encode();
        assert_eq!(buf.len(), p.wire_bytes());
        assert_eq!(Payload::decode(&buf).unwrap(), p);
    }

    #[test]
    fn indexed_roundtrip_with_delta_coding() {
        let p = Payload {
            n: 1000,
            values: vec![3.0, -1.0, 9.5],
            indices: Some(vec![0, 499, 999]),
            key: 7,
            side: vec![],
            codec: Codec::Indexed,
        };
        let buf = p.encode();
        assert_eq!(buf.len(), p.wire_bytes());
        assert_eq!(Payload::decode(&buf).unwrap(), p);
        // small ascending indices cost 1 byte each instead of 4
        let dense = Payload {
            indices: Some(vec![1, 2, 3]),
            ..p.clone()
        };
        assert!(dense.wire_bytes() < p.n * 4);
    }

    #[test]
    fn quantized_roundtrip_all_bit_widths() {
        for bits in [1u8, 3, 7, 8, 13, 24, 31, 32] {
            let max = field_max(bits).min(1 << 24) as f32;
            let values: Vec<f32> =
                (0..50).map(|i| ((i as f32 * 37.0) % (max + 1.0)).floor()).collect();
            let p = Payload {
                n: 50,
                values,
                indices: None,
                key: 1,
                side: vec![-2.0, 2.0],
                codec: Codec::Quantized { bits },
            };
            let buf = p.encode();
            assert_eq!(buf.len(), p.wire_bytes(), "bits={bits}");
            assert_eq!(Payload::decode(&buf).unwrap(), p, "bits={bits}");
        }
    }

    #[test]
    fn quantized_saturating_top_code_survives() {
        // bits = 32: the f32 code rounds up to exactly 2^32; the packer
        // clamps into the field and the clamped value converts back to the
        // identical f32
        let p = Payload {
            n: 2,
            values: vec![4294967296.0, 0.0],
            indices: None,
            key: 0,
            side: vec![0.0, 1.0],
            codec: Codec::Quantized { bits: 32 },
        };
        let got = Payload::decode(&p.encode()).unwrap();
        assert_eq!(got, p);
    }

    #[test]
    fn decode_rejects_corruption() {
        let p = keyed(5, vec![1.0, 2.0, 3.0]);
        let buf = p.encode();
        assert!(Payload::decode(&buf[..3]).is_err(), "missing prefix");
        assert!(Payload::decode(&buf[..buf.len() - 1]).is_err(), "truncated body");
        let mut grown = buf.clone();
        grown.push(0);
        assert!(Payload::decode(&grown).is_err(), "trailing bytes");
        let mut bad_tag = buf.clone();
        bad_tag[4] = 9;
        assert!(Payload::decode(&bad_tag).is_err(), "unknown codec");
    }

    #[test]
    fn decode_rejects_absurd_counts_without_allocating() {
        // hand-built keyed frame claiming ~2^49 values in a 4-byte body:
        // decode must return Err before Vec::with_capacity sees the count
        let mut body = vec![0u8]; // codec tag: Keyed
        body.push(1); // varint n = 1
        body.extend_from_slice(&7u64.to_le_bytes()); // key
        body.push(0); // side_len = 0
        body.extend_from_slice(&[0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0xFF, 0x7F]); // huge m
        body.extend_from_slice(&[0; 4]);
        let mut framed = (body.len() as u32).to_le_bytes().to_vec();
        framed.extend_from_slice(&body);
        let err = Payload::decode(&framed).unwrap_err().to_string();
        assert!(err.contains("exceeds remaining buffer"), "{err}");

        // same for a huge side_len
        let mut body = vec![0u8, 1];
        body.extend_from_slice(&7u64.to_le_bytes());
        body.extend_from_slice(&[0xFF, 0xFF, 0x7F]); // huge side_len
        let mut framed = (body.len() as u32).to_le_bytes().to_vec();
        framed.extend_from_slice(&body);
        assert!(Payload::decode(&framed).is_err());
    }

    #[test]
    fn side_channel_is_bit_exact() {
        let p = Payload {
            n: 3,
            values: vec![0.0, 1.0, 2.0],
            indices: None,
            key: 3,
            side: vec![f32::NEG_INFINITY, 1e-38, 3.25],
            codec: Codec::Keyed,
        };
        let got = Payload::decode(&p.encode()).unwrap();
        assert_eq!(got.side.len(), 3);
        for (a, b) in got.side.iter().zip(&p.side) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
