//! Error-feedback compression (extension; the paper's future-work
//! direction of combining compression with memory).
//!
//! Classic EF / EF21 idea: the encoder remembers the residual each
//! message dropped (`e ← e + x − x̂`) and adds it to the next payload, so
//! dropped mass is *delayed* rather than lost and the bias of the channel
//! vanishes over time.  Wrapped around the paper's shared-key subset
//! mechanism, keyed per (epoch-independent) channel id so each link keeps
//! its own memory.
//!
//! # Residuals across rate changes
//!
//! The steady-state residual magnitude scales with `r − 1` (a coordinate
//! kept with probability 1/r settles at `m* = (r − 1)·x`): memory
//! accumulated at a heavy rate r(t) is *stale* once the schedule moves to
//! a lighter r(t+1) and would otherwise be replayed verbatim, injecting
//! old compression error into a now-nearly-lossless channel.  On every
//! rate transition the residual is rescaled by
//! `(r_new − 1) / (r_old − 1)` (clamped to [0, 1]; zero when the new rate
//! is lossless), matching the new steady state — pinned by the
//! `rate_transition_*` regression tests below.
//!
//! This is stateful, so it does not implement the stateless `Compressor`
//! trait; the ablation harness drives it directly.

use super::subset::RandomSubsetCompressor;
use super::{Compressor, Payload};
use std::collections::HashMap;

/// One channel's memory: the residual plus the rate it was accumulated at.
struct ChannelMemory {
    residual: Vec<f32>,
    last_rate: f32,
}

/// Residual scale factor applied when a channel's rate moves `old -> new`:
/// steady-state residual mass is proportional to `r − 1`, so stale memory
/// is shrunk to the new operating point (never grown).
pub fn residual_scale(old_rate: f32, new_rate: f32) -> f32 {
    if new_rate <= 1.0 {
        0.0 // lossless channel: nothing should be replayed
    } else if old_rate <= 1.0 {
        1.0 // residual is ~0 anyway; keep it
    } else {
        ((new_rate - 1.0) / (old_rate - 1.0)).clamp(0.0, 1.0)
    }
}

/// Stable channel id for a halo send-plan channel.  Residual memory must
/// follow the *plan* — the pruned (layer, sender, receiver) row set —
/// not the receiver's whole boundary block: two senders filling disjoint
/// slots of one boundary buffer are independent channels with their own
/// residuals, and a plan's payload length (its pruned row count × width)
/// is exactly what `ErrorFeedback` keys its length-change reset on.
pub fn plan_channel(layer: usize, from: usize, to: usize) -> u64 {
    (layer as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (from as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F)
        ^ (to as u64).wrapping_mul(0x1656_67B1_9E37_79F9)
        ^ 0x9A10
}

/// Per-channel error-feedback wrapper around the subset compressor.
pub struct ErrorFeedback {
    /// channel id -> residual memory
    memory: HashMap<u64, ChannelMemory>,
}

impl Default for ErrorFeedback {
    fn default() -> Self {
        Self::new()
    }
}

impl ErrorFeedback {
    pub fn new() -> ErrorFeedback {
        ErrorFeedback { memory: HashMap::new() }
    }

    /// Compress `x` on channel `chan` at `rate`, folding in the remembered
    /// residual; updates the residual to what this message drops.  A rate
    /// transition first rescales the memory (see module docs).
    pub fn compress(&mut self, chan: u64, x: &[f32], rate: f32, key: u64) -> Payload {
        let mem = self
            .memory
            .entry(chan)
            .or_insert_with(|| ChannelMemory { residual: vec![0.0; x.len()], last_rate: rate });
        if mem.residual.len() != x.len() {
            mem.residual.clear();
            mem.residual.resize(x.len(), 0.0);
            mem.last_rate = rate;
        }
        if rate != mem.last_rate {
            let s = residual_scale(mem.last_rate, rate);
            if s == 0.0 {
                mem.residual.fill(0.0);
            } else if s < 1.0 {
                for r in mem.residual.iter_mut() {
                    *r *= s;
                }
            }
            mem.last_rate = rate;
        }
        // corrected signal
        let corrected: Vec<f32> =
            x.iter().zip(mem.residual.iter()).map(|(a, b)| a + b).collect();
        let payload = RandomSubsetCompressor.compress(&corrected, rate, key);
        // residual = corrected - decompress(payload)
        let mut xhat = vec![0.0; x.len()];
        RandomSubsetCompressor.decompress(&payload, &mut xhat);
        for ((m, &c), &d) in mem.residual.iter_mut().zip(&corrected).zip(&xhat) {
            *m = c - d;
        }
        payload
    }

    /// Decompression is the plain subset decoder (receiver is stateless).
    pub fn decompress(&self, payload: &Payload, out: &mut [f32]) {
        RandomSubsetCompressor.decompress(payload, out);
    }

    /// Total residual mass currently held (diagnostics).
    pub fn residual_norm(&self, chan: u64) -> f32 {
        self.memory
            .get(&chan)
            .map(|m| m.residual.iter().map(|x| x * x).sum::<f32>().sqrt())
            .unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn residual_carries_dropped_mass_to_later_messages() {
        // a constant signal sent repeatedly at rate 4: without EF the
        // receiver reconstructs 1/4 of the mass every time; with EF the
        // *cumulative* reconstruction converges to the cumulative signal.
        let n = 256;
        let x = vec![1.0f32; n];
        let mut ef = ErrorFeedback::new();
        let mut cum = vec![0.0f32; n];
        let rounds = 16;
        for r in 0..rounds {
            let p = ef.compress(7, &x, 4.0, 1000 + r);
            let mut out = vec![0.0; n];
            ef.decompress(&p, &mut out);
            for (c, o) in cum.iter_mut().zip(&out) {
                *c += o;
            }
        }
        // steady-state residual per coordinate is ~x(1-p)/p = 3, so the
        // cumulative delivery approaches rounds - 3
        let target = rounds as f32;
        let mean: f32 = cum.iter().sum::<f32>() / n as f32;
        assert!(mean > 0.6 * target, "cumulative mean {mean} vs target {target}");
        // plain subset (no EF) delivers only ~1/4 of the mass
        let plain: f32 = rounds as f32 / 4.0;
        assert!(mean > 2.0 * plain, "EF mean {mean} not above plain {plain}");
    }

    #[test]
    fn rate_one_keeps_residual_zero() {
        let mut ef = ErrorFeedback::new();
        let mut rng = Rng::new(3);
        for k in 0..5 {
            let x: Vec<f32> = (0..64).map(|_| rng.next_normal()).collect();
            let p = ef.compress(1, &x, 1.0, k);
            let mut out = vec![0.0; 64];
            ef.decompress(&p, &mut out);
            assert_eq!(out, x);
        }
        assert!(ef.residual_norm(1) < 1e-6);
    }

    #[test]
    fn channels_are_independent() {
        let mut ef = ErrorFeedback::new();
        let x = vec![2.0f32; 32];
        ef.compress(10, &x, 8.0, 1);
        assert!(ef.residual_norm(10) > 0.0);
        assert_eq!(ef.residual_norm(11), 0.0);
    }

    #[test]
    fn plan_channels_are_distinct_and_stable() {
        let mut seen = std::collections::HashSet::new();
        for layer in 0..4 {
            for from in 0..8 {
                for to in 0..8 {
                    if from == to {
                        continue;
                    }
                    assert!(
                        seen.insert(plan_channel(layer, from, to)),
                        "collision at ({layer}, {from}, {to})"
                    );
                }
            }
        }
        assert_eq!(plan_channel(1, 2, 3), plan_channel(1, 2, 3));
        // direction matters: q->p and p->q are separate residual memories
        assert_ne!(plan_channel(0, 1, 2), plan_channel(0, 2, 1));
        // residuals on two plan channels never bleed into each other
        let mut ef = ErrorFeedback::new();
        ef.compress(plan_channel(0, 0, 1), &vec![1.0; 64], 8.0, 1);
        assert!(ef.residual_norm(plan_channel(0, 0, 1)) > 0.0);
        assert_eq!(ef.residual_norm(plan_channel(0, 1, 0)), 0.0);
    }

    #[test]
    fn payload_length_changes_reset_memory() {
        let mut ef = ErrorFeedback::new();
        ef.compress(5, &vec![1.0; 64], 4.0, 1);
        // shorter payload on the same channel: memory must resize, not panic
        let p = ef.compress(5, &vec![1.0; 32], 4.0, 2);
        assert_eq!(p.n, 32);
    }

    #[test]
    fn residual_scale_law() {
        assert_eq!(residual_scale(8.0, 1.0), 0.0); // to lossless: reset
        assert_eq!(residual_scale(8.0, 8.0), 1.0);
        assert!((residual_scale(8.0, 2.0) - 1.0 / 7.0).abs() < 1e-6);
        assert_eq!(residual_scale(2.0, 8.0), 1.0); // never amplified
        assert_eq!(residual_scale(1.0, 4.0), 1.0); // from lossless: keep ~0
    }

    #[test]
    fn rate_transition_to_lossless_resets_stale_residual() {
        // regression: residuals accumulated at rate 8 used to be replayed
        // verbatim when the schedule reached rate 1, corrupting an
        // otherwise lossless message
        let n = 128;
        let x = vec![1.0f32; n];
        let mut ef = ErrorFeedback::new();
        for r in 0..6 {
            ef.compress(3, &x, 8.0, 100 + r);
        }
        assert!(ef.residual_norm(3) > 1.0, "residual built up at rate 8");
        let p = ef.compress(3, &x, 1.0, 999);
        let mut out = vec![0.0; n];
        ef.decompress(&p, &mut out);
        assert_eq!(out, x, "rate-1 message must be exactly x, no stale replay");
        assert!(ef.residual_norm(3) < 1e-6);
    }

    #[test]
    fn per_link_rate_transitions_rescale_only_that_channel() {
        // a link-aware controller moves each (layer, sender, receiver)
        // channel's rate independently: the hot link's transition must
        // rescale *its* residual while the cold link's memory is untouched
        let n = 128;
        let x = vec![1.0f32; n];
        let hot = plan_channel(0, 0, 1);
        let cold = plan_channel(0, 0, 2);
        let mut ef = ErrorFeedback::new();
        for r in 0..6 {
            ef.compress(hot, &x, 16.0, 100 + r);
            ef.compress(cold, &x, 16.0, 500 + r);
        }
        let hot_before = ef.residual_norm(hot);
        let cold_before = ef.residual_norm(cold);
        // next plan: hot link drops to rate 2, cold link keeps rate 16
        ef.compress(hot, &x, 2.0, 700);
        ef.compress(cold, &x, 16.0, 701);
        assert!(
            ef.residual_norm(hot) < 0.5 * hot_before,
            "hot-link residual not rescaled on its rate transition"
        );
        // the cold channel saw no transition: its residual stays at the
        // rate-16 steady state (same signal, so the norm barely moves)
        assert!(
            ef.residual_norm(cold) > 0.5 * cold_before,
            "cold-link residual must not be touched by the hot link's move"
        );
    }

    #[test]
    fn rate_transition_rescales_residual_downward() {
        let n = 256;
        let x = vec![1.0f32; n];
        let mut ef = ErrorFeedback::new();
        for r in 0..8 {
            ef.compress(4, &x, 16.0, 200 + r);
        }
        let before = ef.residual_norm(4);
        // one message at rate 2: memory first shrinks by (2-1)/(16-1),
        // then at most the newly dropped half of the corrected signal is
        // re-accumulated — far below the stale rate-16 mass
        ef.compress(4, &x, 2.0, 300);
        let after = ef.residual_norm(4);
        assert!(
            after < 0.5 * before,
            "stale residual not rescaled: {before} -> {after}"
        );
    }
}
