//! Top-K magnitude compression baseline (ablation): keeps the largest
//! |x_i| but must transmit explicit indices — delta+varint coded on the
//! wire (ascending order makes the gaps small), still costlier per kept
//! element than the paper's shared-key subset at equal K.

use super::{kept_count, Codec, Compressor, Payload};
use crate::util::top_m_indices;

pub struct TopKCompressor;

impl Compressor for TopKCompressor {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn compress(&self, x: &[f32], rate: f32, key: u64) -> Payload {
        let m = kept_count(x.len(), rate);
        let mags: Vec<f32> = x.iter().map(|v| v.abs()).collect();
        // O(n) partial selection; `top_m_indices` returns the same set as
        // the old full argsort (ties keep the lower index), already in the
        // canonical ascending-index order the wire format requires
        let idx = top_m_indices(&mags, m);
        let values = idx.iter().map(|&i| x[i as usize]).collect();
        Payload { n: x.len(), values, indices: Some(idx), key, side: vec![], codec: Codec::Indexed }
    }

    fn decompress(&self, payload: &Payload, out: &mut [f32]) {
        assert_eq!(out.len(), payload.n);
        out.fill(0.0);
        if payload.is_dropped() {
            // lost on the wire: reconstruct zeros (no indices to scatter)
            return;
        }
        let idx = payload.indices.as_ref().expect("topk payload carries indices");
        for (&i, &v) in idx.iter().zip(&payload.values) {
            out[i as usize] = v;
        }
    }

    /// Masking channel: error is exactly the dropped mass.
    fn channel_error(&self, x: &[f32], payload: &Payload) -> (f32, f32) {
        let total: f32 = x.iter().map(|v| v * v).sum();
        let kept: f32 = payload.values.iter().map(|v| v * v).sum();
        ((total - kept).max(0.0), total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_largest_magnitudes() {
        let x = [0.1, -5.0, 0.2, 3.0, -0.05];
        let p = TopKCompressor.compress(&x, 2.5, 0);
        assert_eq!(p.values.len(), 2);
        let mut out = vec![0.0; 5];
        TopKCompressor.decompress(&p, &mut out);
        assert_eq!(out, vec![0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn wire_cost_includes_indices() {
        let x = vec![1.0; 100];
        let p = TopKCompressor.compress(&x, 4.0, 0);
        // 25 values at 4 bytes each, 25 delta-varint indices (1 byte each
        // for these small gaps), plus the fixed header
        let bytes = p.wire_bytes();
        assert!(bytes > 25 * 4 + 25, "bytes {bytes}");
        assert!(bytes < 25 * 4 + 25 + 24, "bytes {bytes}");
        assert_eq!(bytes, p.encode().len());
    }

    #[test]
    fn error_is_minimal_among_masks() {
        let x = [3.0, 1.0, -4.0, 0.5];
        let p = TopKCompressor.compress(&x, 2.0, 0);
        let mut out = vec![0.0; 4];
        TopKCompressor.decompress(&p, &mut out);
        let err: f32 = x.iter().zip(&out).map(|(a, b)| (a - b).powi(2)).sum();
        assert!((err - (1.0 + 0.25)).abs() < 1e-6);
    }
}
