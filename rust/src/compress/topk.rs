//! Top-K magnitude compression baseline (ablation): keeps the largest
//! |x_i| but must transmit explicit indices, doubling per-element wire
//! cost relative to the paper's shared-key subset at equal K.

use super::{kept_count, Compressor, Payload};
use crate::util::top_m_indices;

pub struct TopKCompressor;

impl Compressor for TopKCompressor {
    fn name(&self) -> &'static str {
        "topk"
    }

    fn compress(&self, x: &[f32], rate: f32, key: u64) -> Payload {
        let m = kept_count(x.len(), rate);
        let mags: Vec<f32> = x.iter().map(|v| v.abs()).collect();
        // O(n) partial selection; `top_m_indices` returns the same set as
        // the old full argsort (ties keep the lower index), already in the
        // canonical ascending-index order the wire format requires
        let idx = top_m_indices(&mags, m);
        let values = idx.iter().map(|&i| x[i as usize]).collect();
        Payload { n: x.len(), values, indices: Some(idx), key, side: vec![], wire_override: None }
    }

    fn decompress(&self, payload: &Payload, out: &mut [f32]) {
        assert_eq!(out.len(), payload.n);
        out.fill(0.0);
        let idx = payload.indices.as_ref().expect("topk payload carries indices");
        for (&i, &v) in idx.iter().zip(&payload.values) {
            out[i as usize] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_largest_magnitudes() {
        let x = [0.1, -5.0, 0.2, 3.0, -0.05];
        let p = TopKCompressor.compress(&x, 2.5, 0);
        assert_eq!(p.values.len(), 2);
        let mut out = vec![0.0; 5];
        TopKCompressor.decompress(&p, &mut out);
        assert_eq!(out, vec![0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn wire_cost_includes_indices() {
        let x = vec![1.0; 100];
        let p = TopKCompressor.compress(&x, 4.0, 0);
        assert_eq!(p.wire_floats(), 50); // 25 values + 25 indices
    }

    #[test]
    fn error_is_minimal_among_masks() {
        let x = [3.0, 1.0, -4.0, 0.5];
        let p = TopKCompressor.compress(&x, 2.0, 0);
        let mut out = vec![0.0; 4];
        TopKCompressor.decompress(&p, &mut out);
        let err: f32 = x.iter().zip(&out).map(|(a, b)| (a - b).powi(2)).sum();
        assert!((err - (1.0 + 0.25)).abs() < 1e-6);
    }
}
