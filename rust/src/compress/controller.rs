//! Rate controllers: how the compression rate is *chosen*.
//!
//! The paper replays open-loop schedules r(t) (§IV); AdaQP-style systems
//! instead adapt the channel per message from observed state.  This module
//! unifies both behind [`RateController`]:
//!
//! * [`OpenLoopController`] wraps a [`CommMode`] (Full / None / any
//!   [`Scheduler`](super::Scheduler)) — rates are a pure function of the
//!   epoch, `observe` is a no-op.  All historical behavior lives here.
//! * [`BudgetController`] closes the loop: it consumes a **total byte
//!   budget** plus per-epoch feedback (measured wire bytes per layer from
//!   the ledger, relative compression error from the channel residuals)
//!   and picks next-epoch per-layer rates that spend the budget on a
//!   rising communication ramp while keeping the rate sequence — and with
//!   it Proposition 2's error-decrease contract — non-increasing, enforced
//!   at runtime by clamping every new rate to the previous plan and
//!   backing off whenever the observed relative error rises.
//!
//! Controllers must be deterministic functions of their observation
//! sequence: the trainer feeds them feedback merged in worker-rank order
//! at the epoch barrier, so the parallel runtime stays bitwise equal to
//! the sequential oracle (`tests/parallel_equivalence.rs`).

use super::CommMode;
use crate::comm::LinkModel;
use crate::Result;

/// Which direction a message travels in the per-layer exchange.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChannelKind {
    /// boundary activations, owner -> replica
    Forward,
    /// returned cotangents, replica -> owner
    Backward,
}

/// Per-layer measurements for one epoch (forward + backward combined).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LayerFeedback {
    /// exact wire bytes of this layer's compressed exchanges
    pub bytes: usize,
    /// `Σ ||x − x̂||²` over this layer's messages
    pub err_sq: f32,
    /// `Σ ||x||²` over this layer's messages
    pub sig_sq: f32,
}

impl LayerFeedback {
    /// Fold another cell into this one.  Every merge in the trainer goes
    /// through here, in worker-rank order, so the sequential and parallel
    /// paths cannot drift in f32 accumulation order.
    pub fn merge(&mut self, other: &LayerFeedback) {
        self.bytes += other.bytes;
        self.err_sq += other.err_sq;
        self.sig_sq += other.sig_sq;
    }
}

/// One directed link's traffic over one epoch, measured by the fabric
/// ledger (sorted by `(from, to)` when assembled; merged in rank order
/// for multi-process runs so the observation sequence is deterministic).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LinkCell {
    pub from: usize,
    pub to: usize,
    pub bytes: usize,
    pub msgs: usize,
}

/// One epoch's closed-loop feedback, assembled by the trainer at the
/// epoch barrier (deterministically: worker contributions merged in rank
/// order).
#[derive(Clone, Debug)]
pub struct Feedback {
    pub epoch: usize,
    /// every byte the fabric charged this epoch, including weight sync
    pub total_bytes: usize,
    /// per-layer compressed-exchange measurements
    pub layers: Vec<LayerFeedback>,
    /// the per-layer forward rate that produced them (None = no comm)
    pub rates: Vec<Option<f32>>,
    /// per-(sender, receiver) epoch traffic from the detailed ledger
    /// (empty under the aggregated ledger or when no controller asks)
    pub links: Vec<LinkCell>,
}

impl Feedback {
    /// Bytes spent on compressible (activation/gradient) traffic.
    pub fn data_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.bytes).sum()
    }

    /// Relative compression error `Σ err² / Σ sig²` across layers.
    pub fn rel_error(&self) -> Option<f32> {
        let err: f32 = self.layers.iter().map(|l| l.err_sq).sum();
        let sig: f32 = self.layers.iter().map(|l| l.sig_sq).sum();
        (sig > 0.0).then(|| err / sig)
    }
}

/// Chooses the compression rate for every (epoch, layer, direction) and
/// optionally consumes end-of-epoch feedback.
pub trait RateController: Send + Sync {
    /// Report label (becomes `RunReport::algorithm`).
    fn label(&self) -> String;

    /// Rate for a message; `None` means "do not communicate at all"
    /// (the No-Comm baseline's local-normalization semantics).
    fn rate_for(&self, epoch: usize, layer: usize, kind: ChannelKind) -> Option<f32>;

    /// Rate for a message on a specific directed link.  The default
    /// ignores the link, so open-loop schedules and the uniform
    /// [`BudgetController`] keep their per-(epoch, layer) behavior; a
    /// link-aware controller returns per-(sender, receiver) rates here.
    fn rate_for_link(
        &self,
        epoch: usize,
        layer: usize,
        kind: ChannelKind,
        _from: usize,
        _to: usize,
    ) -> Option<f32> {
        self.rate_for(epoch, layer, kind)
    }

    /// Whether `rate_for_link` can differ from `rate_for`.  When true the
    /// trainer materializes the full per-(layer, sender, receiver) rate
    /// matrix into each epoch plan (and ships it over the dist control
    /// protocol) instead of the scalar per-layer rates.
    fn link_aware(&self) -> bool {
        false
    }

    /// Representative rate for reporting (`EpochRecord::rate`).
    fn nominal_rate(&self, epoch: usize) -> Option<f32> {
        self.rate_for(epoch, 0, ChannelKind::Forward)
    }

    /// Whether the trainer should measure per-layer byte/error feedback
    /// (skipped for open-loop controllers: it costs one extra pass per
    /// compressed message).
    fn wants_feedback(&self) -> bool {
        false
    }

    /// End-of-epoch observation; called once per epoch, after the server
    /// step, with deterministically merged measurements.
    fn observe(&mut self, _fb: &Feedback) {}

    /// Serialize all mutable state (for checkpoint shards).  Stateless
    /// (open-loop) controllers return an empty blob.  Together with
    /// `restore` this is what makes closed-loop crash recovery bitwise:
    /// the driver snapshots the controller into the shard set and a
    /// rewound run replays from exactly the checkpointed plan.
    fn snapshot(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore state captured by `snapshot`.  The default accepts only an
    /// empty blob (stateless controllers have nothing to restore).
    fn restore(&mut self, bytes: &[u8]) -> Result<()> {
        anyhow::ensure!(
            bytes.is_empty(),
            "controller {:?} is stateless but the snapshot carries {} bytes",
            self.label(),
            bytes.len()
        );
        Ok(())
    }
}

/// The historical open-loop path: rates replayed from a [`CommMode`].
pub struct OpenLoopController {
    mode: CommMode,
}

impl OpenLoopController {
    pub fn new(mode: CommMode) -> OpenLoopController {
        OpenLoopController { mode }
    }

    pub fn mode(&self) -> &CommMode {
        &self.mode
    }
}

impl RateController for OpenLoopController {
    fn label(&self) -> String {
        self.mode.label()
    }

    fn rate_for(&self, epoch: usize, _layer: usize, _kind: ChannelKind) -> Option<f32> {
        self.mode.rate_at(epoch)
    }
}

/// Closed-loop controller: spend `budget` wire bytes over `epochs` epochs.
///
/// Planning model (all arithmetic in f64, deterministic):
///
/// * `full_est[l]` — estimated bytes/epoch layer `l` would cost at rate 1,
///   refreshed every epoch from `measured_bytes × rate` (header overhead
///   makes this an overestimate at high rates; it self-corrects as the
///   rate descends).
/// * The remaining *data* budget (total minus observed fixed overhead such
///   as weight sync) is allocated over the remaining epochs on a
///   **quadratic ramp** — epoch t gets weight (t+1)², so communication
///   concentrates late, mirroring the paper's result that decreasing-rate
///   schedules dominate fixed rates at equal spend.
/// * Per epoch, the allowance splits across layers by a 50/50 blend of
///   byte share and error share (layers whose channel hurts more get more
///   bytes — the AdaQP-style assignment).
/// * New rates are clamped into `[1, previous rate]`, so the planned rate
///   sequence is non-increasing per layer (Proposition 2's condition); if
///   the observed relative error still rises epoch-over-epoch, every rate
///   is additionally backed off by 0.7× and the violation is counted.
/// * The budget is a **hard ceiling**: once observed spend reaches it,
///   the controller halts compressible traffic entirely — `rate_for`
///   returns `None` (No-Comm semantics) for the rest of the run, so
///   overspend is bounded by the single epoch in flight when the ceiling
///   is hit (plus trainer-level weight sync, which the controller cannot
///   veto).  The allowance planning exists to make this path unreachable
///   on a feasible budget.
pub struct BudgetController {
    budget: usize,
    epochs: usize,
    c_max: f32,
    /// next-epoch per-layer rate (the current plan)
    plan: Vec<f32>,
    spent: usize,
    epochs_observed: usize,
    /// latest measured non-layer (weight sync etc.) bytes per epoch
    overhead_est: f64,
    /// per-layer bytes/epoch estimate at rate 1
    full_est: Vec<f64>,
    /// budget exhausted: stop communicating instead of overspending
    halted: bool,
    last_rel_err: Option<f32>,
    violations: usize,
}

impl BudgetController {
    pub fn new(budget_bytes: usize, epochs: usize, layers: usize, c_max: f32) -> BudgetController {
        let c_max = c_max.max(1.0);
        BudgetController {
            budget: budget_bytes,
            epochs: epochs.max(1),
            c_max,
            plan: vec![c_max; layers.max(1)],
            spent: 0,
            epochs_observed: 0,
            overhead_est: 0.0,
            full_est: vec![0.0; layers.max(1)],
            halted: false,
            last_rel_err: None,
            violations: 0,
        }
    }

    /// True once the budget is exhausted and data traffic is halted.
    pub fn halted(&self) -> bool {
        self.halted
    }

    /// Total bytes observed so far.
    pub fn spent(&self) -> usize {
        self.spent
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Times the observed relative error rose epoch-over-epoch (each one
    /// triggered a forced rate back-off).
    pub fn violations(&self) -> usize {
        self.violations
    }

    /// The current per-layer plan (next epoch's rates).
    pub fn current_plan(&self) -> &[f32] {
        &self.plan
    }

    /// The configured starting (maximum) rate.
    pub fn c_max(&self) -> f32 {
        self.c_max
    }

    /// Per-layer bytes/epoch estimates at rate 1 (0.0 until observed).
    pub fn full_estimates(&self) -> &[f64] {
        &self.full_est
    }

    /// Estimated aggregate rate of the current plan: full-rate bytes over
    /// planned bytes across layers (None before any observation).
    pub fn planned_aggregate_rate(&self) -> Option<f64> {
        let full: f64 = self.full_est.iter().sum();
        let planned: f64 =
            self.full_est.iter().zip(&self.plan).map(|(f, &r)| f / f64::from(r)).sum();
        (full > 0.0 && planned > 0.0).then(|| full / planned)
    }
}

// ---- snapshot codec (LE, strict) ---------------------------------------

struct SnapReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> SnapReader<'a> {
    fn new(buf: &'a [u8]) -> SnapReader<'a> {
        SnapReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        anyhow::ensure!(
            self.buf.len() - self.pos >= n,
            "controller snapshot: truncated {what} at offset {}",
            self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn f32(&mut self, what: &str) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn opt_f32(&mut self, what: &str) -> Result<Option<f32>> {
        match self.u8(what)? {
            0 => Ok(None),
            1 => Ok(Some(self.f32(what)?)),
            t => anyhow::bail!("controller snapshot: bad option tag {t} in {what}"),
        }
    }

    fn done(&self, what: &str) -> Result<()> {
        anyhow::ensure!(
            self.pos == self.buf.len(),
            "controller snapshot: {} trailing bytes after {what}",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

fn snap_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn snap_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn snap_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn snap_opt_f32(buf: &mut Vec<u8>, v: Option<f32>) {
    match v {
        Some(x) => {
            buf.push(1);
            snap_f32(buf, x);
        }
        None => buf.push(0),
    }
}

impl BudgetController {
    fn snapshot_into(&self, b: &mut Vec<u8>) {
        snap_u64(b, self.plan.len() as u64);
        for &r in &self.plan {
            snap_f32(b, r);
        }
        snap_u64(b, self.spent as u64);
        snap_u64(b, self.epochs_observed as u64);
        snap_f64(b, self.overhead_est);
        for &f in &self.full_est {
            snap_f64(b, f);
        }
        b.push(u8::from(self.halted));
        snap_opt_f32(b, self.last_rel_err);
        snap_u64(b, self.violations as u64);
    }

    fn restore_from(&mut self, r: &mut SnapReader) -> Result<()> {
        let n = r.u64("budget.plan.len")? as usize;
        anyhow::ensure!(
            n == self.plan.len(),
            "budget snapshot has {n} layers, controller has {}",
            self.plan.len()
        );
        for p in self.plan.iter_mut() {
            *p = r.f32("budget.plan")?;
        }
        self.spent = r.u64("budget.spent")? as usize;
        self.epochs_observed = r.u64("budget.epochs_observed")? as usize;
        self.overhead_est = r.f64("budget.overhead_est")?;
        for f in self.full_est.iter_mut() {
            *f = r.f64("budget.full_est")?;
        }
        self.halted = r.u8("budget.halted")? != 0;
        self.last_rel_err = r.opt_f32("budget.last_rel_err")?;
        self.violations = r.u64("budget.violations")? as usize;
        Ok(())
    }
}

impl RateController for BudgetController {
    fn label(&self) -> String {
        format!("budget-{}B", self.budget)
    }

    fn rate_for(&self, _epoch: usize, layer: usize, _kind: ChannelKind) -> Option<f32> {
        if self.halted {
            return None;
        }
        Some(self.plan[layer.min(self.plan.len() - 1)])
    }

    fn nominal_rate(&self, _epoch: usize) -> Option<f32> {
        if self.halted {
            return None;
        }
        // report the cheapest (= most communicative) layer's rate
        Some(self.plan.iter().copied().fold(f32::INFINITY, f32::min))
    }

    fn wants_feedback(&self) -> bool {
        true
    }

    fn observe(&mut self, fb: &Feedback) {
        self.spent += fb.total_bytes;
        self.epochs_observed += 1;
        let data = fb.data_bytes();
        self.overhead_est = fb.total_bytes.saturating_sub(data) as f64;
        let layers = self.plan.len();
        for l in 0..layers {
            let (bytes, rate) = fb
                .layers
                .get(l)
                .map(|m| (m.bytes, fb.rates.get(l).copied().flatten()))
                .unwrap_or((0, None));
            if let (true, Some(r)) = (bytes > 0, rate) {
                self.full_est[l] = bytes as f64 * f64::from(r);
            }
        }

        let done = self.epochs_observed;
        let remaining_epochs = self.epochs.saturating_sub(done);
        if remaining_epochs == 0 {
            return;
        }
        // hard ceiling: once the budget is actually gone, stop data
        // traffic instead of spending on at the frozen plan (overspend is
        // bounded by the one epoch in flight when the ceiling is hit; the
        // allowance planning below exists to never reach this point)
        self.halted = self.spent >= self.budget;
        if self.halted {
            return;
        }
        let remaining = self.budget.saturating_sub(self.spent) as f64;
        let avail = (remaining - self.overhead_est * remaining_epochs as f64).max(0.0);

        // quadratic ramp over the remaining epochs: weight(t) = (t+1)²
        let wsum: f64 = (done..self.epochs).map(|t| ((t + 1) * (t + 1)) as f64).sum();
        let this_w = ((done + 1) * (done + 1)) as f64;
        let allowance = if wsum > 0.0 { avail * this_w / wsum } else { 0.0 };

        let err_tot: f64 = fb.layers.iter().map(|l| f64::from(l.err_sq)).sum();
        let full_tot: f64 = self.full_est.iter().sum();
        if allowance > 0.0 && full_tot > 0.0 {
            for l in 0..layers {
                let byte_share = self.full_est[l] / full_tot;
                let err_share = if err_tot > 0.0 {
                    fb.layers.get(l).map(|m| f64::from(m.err_sq)).unwrap_or(0.0) / err_tot
                } else {
                    byte_share
                };
                let share = 0.5 * byte_share + 0.5 * err_share;
                let a_l = allowance * share;
                if a_l > 0.0 && self.full_est[l] > 0.0 {
                    let target = (self.full_est[l] / a_l) as f32;
                    self.plan[l] = target.clamp(1.0, self.plan[l]);
                }
            }
        }

        // Proposition 2 runtime guard: the error sequence must not grow
        if let (Some(rel), Some(last)) = (fb.rel_error(), self.last_rel_err) {
            if rel > last + 1e-6 {
                self.violations += 1;
                for p in self.plan.iter_mut() {
                    *p = (*p * 0.7).max(1.0);
                }
            }
        }
        if let Some(rel) = fb.rel_error() {
            self.last_rel_err = Some(rel);
        }
    }

    fn snapshot(&self) -> Vec<u8> {
        let mut b = Vec::new();
        self.snapshot_into(&mut b);
        b
    }

    fn restore(&mut self, bytes: &[u8]) -> Result<()> {
        let mut r = SnapReader::new(bytes);
        self.restore_from(&mut r)?;
        r.done("budget snapshot")
    }
}

/// Link-aware budget controller: the uniform [`BudgetController`] decides
/// *how many* bytes each epoch spends (budget pacing, per-layer split,
/// Prop. 2 per-layer clamp, error backoff, hard halt); on top, a
/// water-filling allocation redistributes those bytes across the
/// (sender, receiver) links so the estimated per-link completion time
/// `alpha * msgs + beta * bytes` is equalized — which minimizes
/// [`LinkModel::bottleneck_seconds`] at the same total spend.  Hot links
/// (partition-induced skew, CAGNET-style) compress harder, idle links
/// spare their bytes (AdaQP-style assignment, arXiv 2306.01381).
///
/// Mechanics, per `observe`:
///
/// 1. Per-link full-byte estimates refresh from the ledger's epoch link
///    cells: `F_ij = bytes_ij * r̄ * mult_ij`, where `r̄` is the
///    byte-weighted aggregate of the uniform per-layer rates and
///    `mult_ij` the multiplier that produced those bytes.
/// 2. The uniform plan's next-epoch bytes per link,
///    `u_ij = F_ij / r_next`, give the byte pool `U = Σ u_ij`.
/// 3. Bisection on the water level λ solves
///    `Σ clamp((λ − α·msgs_ij)/β, F_ij/c_max, F_ij) = U`; the clamp keeps
///    every link's rate inside `[1, c_max]`.
/// 4. The **aggregate** Prop. 2 clamp: if the allocation's estimated
///    aggregate rate `ΣF / Σs` would exceed the previous epoch's, all
///    allocations are scaled up (toward lighter compression) until it
///    does not — heterogeneous per-link rates may individually rise, but
///    the aggregate compression error keeps its non-increasing contract.
/// 5. `rate_for_link` returns `inner_rate(layer) * (u_ij / s_ij)`,
///    clamped to `[1, c_max]`.
///
/// Everything is f64 bisection with a fixed iteration count, so the
/// allocation is a deterministic function of the observation sequence and
/// parallel == sequential == tcp stays bitwise.
pub struct LinkAwareBudgetController {
    inner: BudgetController,
    q: usize,
    link: LinkModel,
    /// full-byte estimate per directed link, dense `[from * q + to]`
    link_full: Vec<f64>,
    /// message-count estimate per directed link
    link_msgs: Vec<f64>,
    /// rate multiplier per directed link applied on top of the uniform plan
    mult: Vec<f32>,
    /// previous epoch's estimated aggregate rate (Prop. 2 ceiling)
    last_agg_rate: Option<f64>,
}

impl LinkAwareBudgetController {
    pub fn new(
        budget_bytes: usize,
        epochs: usize,
        layers: usize,
        c_max: f32,
        q: usize,
        link: LinkModel,
    ) -> LinkAwareBudgetController {
        let q = q.max(1);
        LinkAwareBudgetController {
            inner: BudgetController::new(budget_bytes, epochs, layers, c_max),
            q,
            link,
            link_full: vec![0.0; q * q],
            link_msgs: vec![0.0; q * q],
            mult: vec![1.0; q * q],
            last_agg_rate: None,
        }
    }

    pub fn inner(&self) -> &BudgetController {
        &self.inner
    }

    /// The current per-link rate multipliers, dense `[from * q + to]`.
    pub fn multipliers(&self) -> &[f32] {
        &self.mult
    }

    /// Estimated aggregate rate of the current link allocation.
    pub fn aggregate_rate(&self) -> Option<f64> {
        self.last_agg_rate
    }

    fn idx(&self, from: usize, to: usize) -> Option<usize> {
        (from < self.q && to < self.q).then(|| from * self.q + to)
    }

    /// Recompute the per-link multipliers from the refreshed estimates.
    fn replan_links(&mut self) {
        let Some(r_next) = self.inner.planned_aggregate_rate() else {
            return;
        };
        let c_max = f64::from(self.inner.c_max());
        let alpha = self.link.alpha;
        let beta = self.link.beta.max(1e-18);
        // active links and the uniform plan's byte pool over them
        let active: Vec<usize> =
            (0..self.q * self.q).filter(|&i| self.link_full[i] > 0.0).collect();
        if active.len() < 2 {
            return; // nothing to redistribute
        }
        let pool: f64 = active.iter().map(|&i| self.link_full[i] / r_next).sum();
        let lo: Vec<f64> = active.iter().map(|&i| self.link_full[i] / c_max).collect();
        let hi: Vec<f64> = active.iter().map(|&i| self.link_full[i]).collect();
        let pool = pool.clamp(lo.iter().sum::<f64>(), hi.iter().sum::<f64>());
        // bisection on the water level: each link's bytes are the level
        // minus its fixed latency cost, clamped into [lo, hi]
        let fill = |lam: f64| -> Vec<f64> {
            active
                .iter()
                .enumerate()
                .map(|(k, &i)| {
                    ((lam - alpha * self.link_msgs[i]) / beta).clamp(lo[k], hi[k])
                })
                .collect()
        };
        let mut lam_lo = f64::INFINITY;
        let mut lam_hi = f64::NEG_INFINITY;
        for (k, &i) in active.iter().enumerate() {
            lam_lo = lam_lo.min(alpha * self.link_msgs[i] + beta * lo[k]);
            lam_hi = lam_hi.max(alpha * self.link_msgs[i] + beta * hi[k]);
        }
        for _ in 0..64 {
            let mid = 0.5 * (lam_lo + lam_hi);
            if fill(mid).iter().sum::<f64>() < pool {
                lam_lo = mid;
            } else {
                lam_hi = mid;
            }
        }
        let mut alloc = fill(0.5 * (lam_lo + lam_hi));
        // exact-pool rescale (bisection residue), then the aggregate
        // Prop. 2 clamp: estimated aggregate rate must not rise
        let total: f64 = alloc.iter().sum();
        if total > 0.0 {
            let s = pool / total;
            for (k, a) in alloc.iter_mut().enumerate() {
                *a = (*a * s).clamp(lo[k], hi[k]);
            }
        }
        let full_tot: f64 = hi.iter().sum();
        let agg = |alloc: &[f64]| -> f64 {
            let spent: f64 = alloc.iter().sum();
            if spent > 0.0 {
                full_tot / spent
            } else {
                c_max
            }
        };
        let mut rate = agg(&alloc);
        if let Some(prev) = self.last_agg_rate {
            if rate > prev {
                let scale = rate / prev; // spend more to keep error falling
                for (k, a) in alloc.iter_mut().enumerate() {
                    *a = (*a * scale).min(hi[k]);
                }
                rate = agg(&alloc);
            }
        }
        self.last_agg_rate = Some(rate.min(self.last_agg_rate.unwrap_or(f64::INFINITY)));
        for (k, &i) in active.iter().enumerate() {
            let uniform = self.link_full[i] / r_next;
            self.mult[i] = if alloc[k] > 0.0 {
                ((uniform / alloc[k]) as f32).clamp(1.0 / self.inner.c_max(), self.inner.c_max())
            } else {
                1.0
            };
        }
    }
}

impl RateController for LinkAwareBudgetController {
    fn label(&self) -> String {
        format!("{}-linkaware", self.inner.label())
    }

    fn rate_for(&self, epoch: usize, layer: usize, kind: ChannelKind) -> Option<f32> {
        self.inner.rate_for(epoch, layer, kind)
    }

    fn rate_for_link(
        &self,
        epoch: usize,
        layer: usize,
        kind: ChannelKind,
        from: usize,
        to: usize,
    ) -> Option<f32> {
        let base = self.inner.rate_for(epoch, layer, kind)?;
        let mult = self.idx(from, to).map(|i| self.mult[i]).unwrap_or(1.0);
        Some((base * mult).clamp(1.0, self.inner.c_max()))
    }

    fn link_aware(&self) -> bool {
        true
    }

    fn nominal_rate(&self, epoch: usize) -> Option<f32> {
        self.inner.nominal_rate(epoch)
    }

    fn wants_feedback(&self) -> bool {
        true
    }

    fn observe(&mut self, fb: &Feedback) {
        // refresh per-link estimates before the inner replan: the
        // measured bytes were produced by the *current* multipliers
        let wb: f64 = fb
            .layers
            .iter()
            .zip(&fb.rates)
            .filter_map(|(l, r)| r.map(|r| l.bytes as f64 * f64::from(r)))
            .sum();
        let bytes_tot: f64 = fb.layers.iter().map(|l| l.bytes as f64).sum();
        let r_bar = if bytes_tot > 0.0 { wb / bytes_tot } else { 0.0 };
        for cell in &fb.links {
            let Some(i) = self.idx(cell.from, cell.to) else { continue };
            if cell.bytes > 0 && r_bar > 0.0 {
                self.link_full[i] = cell.bytes as f64 * r_bar * f64::from(self.mult[i]);
                self.link_msgs[i] = cell.msgs as f64;
            }
        }
        self.inner.observe(fb);
        if !self.inner.halted() {
            self.replan_links();
        }
    }

    fn snapshot(&self) -> Vec<u8> {
        let mut b = Vec::new();
        self.inner.snapshot_into(&mut b);
        snap_u64(&mut b, self.q as u64);
        for &f in &self.link_full {
            snap_f64(&mut b, f);
        }
        for &m in &self.link_msgs {
            snap_f64(&mut b, m);
        }
        for &m in &self.mult {
            snap_f32(&mut b, m);
        }
        match self.last_agg_rate {
            Some(r) => {
                b.push(1);
                snap_f64(&mut b, r);
            }
            None => b.push(0),
        }
        b
    }

    fn restore(&mut self, bytes: &[u8]) -> Result<()> {
        let mut r = SnapReader::new(bytes);
        self.inner.restore_from(&mut r)?;
        let q = r.u64("linkaware.q")? as usize;
        anyhow::ensure!(q == self.q, "linkaware snapshot is for q={q}, controller has q={}", self.q);
        for f in self.link_full.iter_mut() {
            *f = r.f64("linkaware.link_full")?;
        }
        for m in self.link_msgs.iter_mut() {
            *m = r.f64("linkaware.link_msgs")?;
        }
        for m in self.mult.iter_mut() {
            *m = r.f32("linkaware.mult")?;
        }
        self.last_agg_rate = match r.u8("linkaware.agg tag")? {
            0 => None,
            1 => Some(r.f64("linkaware.agg")?),
            t => anyhow::bail!("controller snapshot: bad option tag {t} in linkaware.agg"),
        };
        r.done("linkaware snapshot")
    }
}

#[cfg(test)]
mod tests {
    use super::super::Scheduler;
    use super::*;

    fn fb(epoch: usize, total: usize, per_layer: &[(usize, f32, f32)], rates: &[f32]) -> Feedback {
        Feedback {
            epoch,
            total_bytes: total,
            layers: per_layer
                .iter()
                .map(|&(bytes, err_sq, sig_sq)| LayerFeedback { bytes, err_sq, sig_sq })
                .collect(),
            rates: rates.iter().map(|&r| Some(r)).collect(),
            links: Vec::new(),
        }
    }

    #[test]
    fn open_loop_mirrors_comm_mode() {
        let c = OpenLoopController::new(CommMode::Full);
        assert_eq!(c.rate_for(3, 1, ChannelKind::Forward), Some(1.0));
        assert_eq!(c.label(), "full-comm");
        assert!(!c.wants_feedback());
        let n = OpenLoopController::new(CommMode::None);
        assert_eq!(n.rate_for(0, 0, ChannelKind::Backward), None);
        let s = OpenLoopController::new(CommMode::Compressed(Scheduler::Fixed { rate: 4.0 }));
        assert_eq!(s.rate_for(9, 2, ChannelKind::Forward), Some(4.0));
        assert_eq!(s.label(), "fixed-r4");
    }

    #[test]
    fn budget_starts_at_c_max_and_never_raises_rates() {
        let mut c = BudgetController::new(1_000_000, 10, 3, 128.0);
        assert_eq!(c.rate_for(0, 0, ChannelKind::Forward), Some(128.0));
        assert!(c.wants_feedback());
        let mut prev = vec![128.0f32; 3];
        for e in 0..9 {
            // generous budget: rates should descend towards 1
            c.observe(&fb(
                e,
                2_000,
                &[(600, 5.0, 10.0), (700, 3.0, 10.0), (700, 2.0, 10.0)],
                &prev,
            ));
            let cur: Vec<f32> = (0..3)
                .map(|l| c.rate_for(e + 1, l, ChannelKind::Forward).unwrap())
                .collect();
            for (l, (&p, &n)) in prev.iter().zip(&cur).enumerate() {
                assert!(n <= p + 1e-6, "layer {l} rate rose: {p} -> {n}");
                assert!(n >= 1.0);
            }
            prev = cur;
        }
        // with a huge budget the plan must have descended substantially
        assert!(prev.iter().all(|&r| r < 64.0), "plan {prev:?}");
    }

    #[test]
    fn budget_holds_high_rate_when_budget_tight() {
        let mut c = BudgetController::new(10_000, 100, 2, 64.0);
        // each epoch already spends 1/50 of the budget at rate 64: no room
        for e in 0..20 {
            c.observe(&fb(e, 200, &[(100, 1.0, 2.0), (100, 1.0, 2.0)], &[64.0, 64.0]));
        }
        let r = c.rate_for(20, 0, ChannelKind::Forward).unwrap();
        assert!(r > 32.0, "tight budget must keep compressing hard, got {r}");
    }

    #[test]
    fn error_rise_triggers_backoff_and_counts_violation() {
        // budget so tight the allowance never lowers the plan on its own:
        // the only way down is the error guard
        let mut c = BudgetController::new(10_000, 50, 1, 32.0);
        c.observe(&fb(0, 100, &[(100, 1.0, 10.0)], &[32.0]));
        let r1 = c.rate_for(1, 0, ChannelKind::Forward).unwrap();
        assert_eq!(c.violations(), 0);
        assert!((r1 - 32.0).abs() < 1e-5, "tight budget should hold c_max, got {r1}");
        // relative error quadruples: guard must back the plan off
        c.observe(&fb(1, 100, &[(100, 4.0, 10.0)], &[r1]));
        let r2 = c.rate_for(2, 0, ChannelKind::Forward).unwrap();
        assert_eq!(c.violations(), 1);
        assert!(r2 <= r1 * 0.7 + 1e-4, "{r1} -> {r2}");
    }

    #[test]
    fn exhausted_budget_halts_communication() {
        // infeasible budget: 100 epochs of 200 B against a 1 kB ceiling —
        // once spend crosses it, the controller must go silent instead of
        // spending at the frozen plan forever
        let mut c = BudgetController::new(1_000, 100, 2, 64.0);
        let mut halted_at = None;
        for e in 0..10 {
            if c.rate_for(e, 0, ChannelKind::Forward).is_none() {
                halted_at = Some(e);
                break;
            }
            c.observe(&fb(e, 200, &[(100, 1.0, 2.0), (100, 1.0, 2.0)], &[64.0, 64.0]));
        }
        let at = halted_at.expect("controller never halted on an infeasible budget");
        assert_eq!(at, 5, "spend crosses 1000 B after the 5th 200 B epoch");
        assert!(c.halted());
        assert_eq!(c.nominal_rate(at), None);
        assert_eq!(c.rate_for(at, 1, ChannelKind::Backward), None);
        assert!(c.spent() >= c.budget());
        // overspend is bounded by the epoch in flight at the crossing
        assert!(c.spent() <= c.budget() + 200);
    }

    #[test]
    fn spend_tracking_and_label() {
        let mut c = BudgetController::new(5_000, 4, 2, 128.0);
        c.observe(&fb(0, 1_200, &[(500, 1.0, 4.0), (500, 1.0, 4.0)], &[128.0, 128.0]));
        assert_eq!(c.spent(), 1_200);
        assert_eq!(c.budget(), 5_000);
        assert_eq!(c.label(), "budget-5000B");
        assert_eq!(c.current_plan().len(), 2);
    }

    fn fbl(
        epoch: usize,
        total: usize,
        per_layer: &[(usize, f32, f32)],
        rates: &[f32],
        links: &[(usize, usize, usize, usize)],
    ) -> Feedback {
        let mut f = fb(epoch, total, per_layer, rates);
        f.links = links
            .iter()
            .map(|&(from, to, bytes, msgs)| LinkCell { from, to, bytes, msgs })
            .collect();
        f
    }

    #[test]
    fn default_rate_for_link_ignores_the_link() {
        let c = OpenLoopController::new(CommMode::Compressed(Scheduler::Fixed { rate: 4.0 }));
        assert!(!c.link_aware());
        assert_eq!(
            c.rate_for_link(2, 1, ChannelKind::Forward, 0, 3),
            c.rate_for(2, 1, ChannelKind::Forward)
        );
        let b = BudgetController::new(10_000, 5, 2, 32.0);
        assert!(!b.link_aware());
        assert_eq!(
            b.rate_for_link(0, 1, ChannelKind::Backward, 1, 0),
            b.rate_for(0, 1, ChannelKind::Backward)
        );
    }

    #[test]
    fn linkaware_hot_link_compresses_harder() {
        let mut c =
            LinkAwareBudgetController::new(1_000_000, 10, 1, 64.0, 2, LinkModel::ten_gbe());
        assert!(c.link_aware());
        assert!(c.label().ends_with("-linkaware"));
        // before any feedback every link runs the uniform plan
        assert_eq!(
            c.rate_for_link(0, 0, ChannelKind::Forward, 0, 1),
            c.rate_for(0, 0, ChannelKind::Forward)
        );
        // skewed partition: link 0->1 carries 3x the bytes of 1->0
        c.observe(&fbl(
            0,
            2_000,
            &[(2_000, 1.0, 10.0)],
            &[64.0],
            &[(0, 1, 1_500, 3), (1, 0, 500, 3)],
        ));
        let base = c.rate_for(1, 0, ChannelKind::Forward).unwrap();
        assert!(base > 1.0 && base < 64.0, "plan should have descended, got {base}");
        let hot = c.rate_for_link(1, 0, ChannelKind::Forward, 0, 1).unwrap();
        let cold = c.rate_for_link(1, 0, ChannelKind::Forward, 1, 0).unwrap();
        assert!(
            hot > base && base > cold,
            "water-fill must bracket the uniform rate: hot {hot} / base {base} / cold {cold}"
        );
        // the multipliers are what the allocation redistributed
        let m = c.multipliers();
        assert!(m[1] > 1.0 && m[2] < 1.0, "multipliers {m:?}");
        // out-of-range ranks fall back to the uniform rate
        assert_eq!(c.rate_for_link(1, 0, ChannelKind::Forward, 0, 9), Some(base));
    }

    #[test]
    fn linkaware_aggregate_rate_never_rises_under_flapping_skew() {
        // skew that flips every epoch would bounce the raw allocation's
        // aggregate rate; the Prop. 2 clamp must keep the estimate (and
        // with it the aggregate error contract) non-increasing
        let mut c = LinkAwareBudgetController::new(200_000, 12, 1, 64.0, 2, LinkModel::ten_gbe());
        let mut prev_agg: Option<f64> = None;
        let mut r = 64.0f32;
        for e in 0..10 {
            let (a, b) = if e % 2 == 0 { (1_600, 400) } else { (400, 1_600) };
            c.observe(&fbl(
                e,
                2_000,
                &[(2_000, 1.0, 10.0)],
                &[r],
                &[(0, 1, a, 5), (1, 0, b, 5)],
            ));
            if let Some(cur) = c.aggregate_rate() {
                if let Some(p) = prev_agg {
                    assert!(cur <= p + 1e-9, "aggregate rate rose at epoch {e}: {p} -> {cur}");
                }
                prev_agg = Some(cur);
            }
            for m in c.multipliers() {
                assert!((1.0 / 64.0..=64.0).contains(m), "multiplier out of range: {m}");
            }
            if let Some(rate) = c.rate_for(e + 1, 0, ChannelKind::Forward) {
                for (from, to) in [(0, 1), (1, 0)] {
                    let lr = c.rate_for_link(e + 1, 0, ChannelKind::Forward, from, to).unwrap();
                    assert!((1.0..=64.0).contains(&lr), "link rate out of range: {lr}");
                }
                r = rate;
            }
        }
        assert!(prev_agg.is_some(), "allocation never produced an aggregate estimate");
    }

    #[test]
    fn budget_snapshot_restore_roundtrip() {
        let mut a = BudgetController::new(50_000, 10, 2, 64.0);
        for e in 0..3 {
            let r: Vec<f32> = (0..2)
                .map(|l| a.rate_for(e, l, ChannelKind::Forward).unwrap())
                .collect();
            a.observe(&fb(e, 2_000, &[(1_000, 1.0, 4.0), (1_000, 2.0, 4.0)], &r));
        }
        let snap = a.snapshot();
        let mut b = BudgetController::new(50_000, 10, 2, 64.0);
        b.restore(&snap).unwrap();
        assert_eq!(b.spent(), a.spent());
        assert_eq!(b.violations(), a.violations());
        assert_eq!(b.current_plan(), a.current_plan());
        assert_eq!(b.full_estimates(), a.full_estimates());
        for l in 0..2 {
            assert_eq!(
                b.rate_for(3, l, ChannelKind::Forward),
                a.rate_for(3, l, ChannelKind::Forward)
            );
        }
        // truncated snapshots error instead of mis-restoring
        assert!(b.restore(&snap[..snap.len() - 1]).is_err());
        // wrong layer count errors
        let mut w = BudgetController::new(50_000, 10, 3, 64.0);
        assert!(w.restore(&snap).is_err());
    }

    #[test]
    fn linkaware_snapshot_restore_roundtrip() {
        let mk = || LinkAwareBudgetController::new(1_000_000, 10, 1, 64.0, 2, LinkModel::ten_gbe());
        let mut a = mk();
        let mut r = 64.0f32;
        for e in 0..3 {
            a.observe(&fbl(
                e,
                2_000,
                &[(2_000, 1.0, 10.0)],
                &[r],
                &[(0, 1, 1_500, 3), (1, 0, 500, 3)],
            ));
            r = a.rate_for(e + 1, 0, ChannelKind::Forward).unwrap();
        }
        let snap = a.snapshot();
        let mut b = mk();
        b.restore(&snap).unwrap();
        assert_eq!(b.multipliers(), a.multipliers());
        assert_eq!(b.aggregate_rate(), a.aggregate_rate());
        assert_eq!(b.inner().spent(), a.inner().spent());
        for (from, to) in [(0, 1), (1, 0)] {
            assert_eq!(
                b.rate_for_link(3, 0, ChannelKind::Forward, from, to),
                a.rate_for_link(3, 0, ChannelKind::Forward, from, to)
            );
        }
        // and the restored controller keeps evolving identically
        let next = fbl(3, 2_000, &[(2_000, 0.8, 10.0)], &[r], &[(0, 1, 1_200, 3), (1, 0, 800, 3)]);
        a.observe(&next);
        b.observe(&next);
        assert_eq!(b.multipliers(), a.multipliers());
        // a q=2 snapshot must not restore into a q=3 controller
        let mut wrong =
            LinkAwareBudgetController::new(1_000_000, 10, 1, 64.0, 3, LinkModel::ten_gbe());
        assert!(wrong.restore(&snap).is_err());
    }

    #[test]
    fn open_loop_snapshot_is_empty_and_restore_is_strict() {
        let mut c = OpenLoopController::new(CommMode::Full);
        assert!(c.snapshot().is_empty());
        assert!(c.restore(&[]).is_ok());
        assert!(c.restore(&[1, 2, 3]).is_err());
    }

    #[test]
    fn ramp_concentrates_bytes_late() {
        // simulate a run where full-comm costs 1000 B/layer-epoch and the
        // budget is exactly half of full spend: the planned rate sequence
        // must descend monotonically to ~1 by the final epochs
        let epochs = 30;
        let mut c = BudgetController::new(15_000, epochs, 1, 128.0);
        let mut rates = vec![c.rate_for(0, 0, ChannelKind::Forward).unwrap()];
        let mut spent_model = 0usize;
        for e in 0..epochs - 1 {
            let r = *rates.last().unwrap();
            let bytes = (1000.0 / r).ceil() as usize;
            spent_model += bytes;
            c.observe(&fb(e, bytes, &[(bytes, 1.0 / r, 10.0)], &[r]));
            rates.push(c.rate_for(e + 1, 0, ChannelKind::Forward).unwrap());
        }
        assert!(rates.windows(2).all(|w| w[1] <= w[0] + 1e-6), "{rates:?}");
        let last = *rates.last().unwrap();
        assert!(last < 4.0, "final rate {last} should approach 1, rates {rates:?}");
        // ceil() rounding can leak ≤ 1 byte per epoch past the allowance
        assert!(spent_model <= 15_000 + epochs, "model overspent: {spent_model}");
    }
}
