//! Rate controllers: how the compression rate is *chosen*.
//!
//! The paper replays open-loop schedules r(t) (§IV); AdaQP-style systems
//! instead adapt the channel per message from observed state.  This module
//! unifies both behind [`RateController`]:
//!
//! * [`OpenLoopController`] wraps a [`CommMode`] (Full / None / any
//!   [`Scheduler`](super::Scheduler)) — rates are a pure function of the
//!   epoch, `observe` is a no-op.  All historical behavior lives here.
//! * [`BudgetController`] closes the loop: it consumes a **total byte
//!   budget** plus per-epoch feedback (measured wire bytes per layer from
//!   the ledger, relative compression error from the channel residuals)
//!   and picks next-epoch per-layer rates that spend the budget on a
//!   rising communication ramp while keeping the rate sequence — and with
//!   it Proposition 2's error-decrease contract — non-increasing, enforced
//!   at runtime by clamping every new rate to the previous plan and
//!   backing off whenever the observed relative error rises.
//!
//! Controllers must be deterministic functions of their observation
//! sequence: the trainer feeds them feedback merged in worker-rank order
//! at the epoch barrier, so the parallel runtime stays bitwise equal to
//! the sequential oracle (`tests/parallel_equivalence.rs`).

use super::CommMode;

/// Which direction a message travels in the per-layer exchange.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChannelKind {
    /// boundary activations, owner -> replica
    Forward,
    /// returned cotangents, replica -> owner
    Backward,
}

/// Per-layer measurements for one epoch (forward + backward combined).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LayerFeedback {
    /// exact wire bytes of this layer's compressed exchanges
    pub bytes: usize,
    /// `Σ ||x − x̂||²` over this layer's messages
    pub err_sq: f32,
    /// `Σ ||x||²` over this layer's messages
    pub sig_sq: f32,
}

impl LayerFeedback {
    /// Fold another cell into this one.  Every merge in the trainer goes
    /// through here, in worker-rank order, so the sequential and parallel
    /// paths cannot drift in f32 accumulation order.
    pub fn merge(&mut self, other: &LayerFeedback) {
        self.bytes += other.bytes;
        self.err_sq += other.err_sq;
        self.sig_sq += other.sig_sq;
    }
}

/// One epoch's closed-loop feedback, assembled by the trainer at the
/// epoch barrier (deterministically: worker contributions merged in rank
/// order).
#[derive(Clone, Debug)]
pub struct Feedback {
    pub epoch: usize,
    /// every byte the fabric charged this epoch, including weight sync
    pub total_bytes: usize,
    /// per-layer compressed-exchange measurements
    pub layers: Vec<LayerFeedback>,
    /// the per-layer forward rate that produced them (None = no comm)
    pub rates: Vec<Option<f32>>,
}

impl Feedback {
    /// Bytes spent on compressible (activation/gradient) traffic.
    pub fn data_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.bytes).sum()
    }

    /// Relative compression error `Σ err² / Σ sig²` across layers.
    pub fn rel_error(&self) -> Option<f32> {
        let err: f32 = self.layers.iter().map(|l| l.err_sq).sum();
        let sig: f32 = self.layers.iter().map(|l| l.sig_sq).sum();
        (sig > 0.0).then(|| err / sig)
    }
}

/// Chooses the compression rate for every (epoch, layer, direction) and
/// optionally consumes end-of-epoch feedback.
pub trait RateController: Send + Sync {
    /// Report label (becomes `RunReport::algorithm`).
    fn label(&self) -> String;

    /// Rate for a message; `None` means "do not communicate at all"
    /// (the No-Comm baseline's local-normalization semantics).
    fn rate_for(&self, epoch: usize, layer: usize, kind: ChannelKind) -> Option<f32>;

    /// Representative rate for reporting (`EpochRecord::rate`).
    fn nominal_rate(&self, epoch: usize) -> Option<f32> {
        self.rate_for(epoch, 0, ChannelKind::Forward)
    }

    /// Whether the trainer should measure per-layer byte/error feedback
    /// (skipped for open-loop controllers: it costs one extra pass per
    /// compressed message).
    fn wants_feedback(&self) -> bool {
        false
    }

    /// End-of-epoch observation; called once per epoch, after the server
    /// step, with deterministically merged measurements.
    fn observe(&mut self, _fb: &Feedback) {}
}

/// The historical open-loop path: rates replayed from a [`CommMode`].
pub struct OpenLoopController {
    mode: CommMode,
}

impl OpenLoopController {
    pub fn new(mode: CommMode) -> OpenLoopController {
        OpenLoopController { mode }
    }

    pub fn mode(&self) -> &CommMode {
        &self.mode
    }
}

impl RateController for OpenLoopController {
    fn label(&self) -> String {
        self.mode.label()
    }

    fn rate_for(&self, epoch: usize, _layer: usize, _kind: ChannelKind) -> Option<f32> {
        self.mode.rate_at(epoch)
    }
}

/// Closed-loop controller: spend `budget` wire bytes over `epochs` epochs.
///
/// Planning model (all arithmetic in f64, deterministic):
///
/// * `full_est[l]` — estimated bytes/epoch layer `l` would cost at rate 1,
///   refreshed every epoch from `measured_bytes × rate` (header overhead
///   makes this an overestimate at high rates; it self-corrects as the
///   rate descends).
/// * The remaining *data* budget (total minus observed fixed overhead such
///   as weight sync) is allocated over the remaining epochs on a
///   **quadratic ramp** — epoch t gets weight (t+1)², so communication
///   concentrates late, mirroring the paper's result that decreasing-rate
///   schedules dominate fixed rates at equal spend.
/// * Per epoch, the allowance splits across layers by a 50/50 blend of
///   byte share and error share (layers whose channel hurts more get more
///   bytes — the AdaQP-style assignment).
/// * New rates are clamped into `[1, previous rate]`, so the planned rate
///   sequence is non-increasing per layer (Proposition 2's condition); if
///   the observed relative error still rises epoch-over-epoch, every rate
///   is additionally backed off by 0.7× and the violation is counted.
/// * The budget is a **hard ceiling**: once observed spend reaches it,
///   the controller halts compressible traffic entirely — `rate_for`
///   returns `None` (No-Comm semantics) for the rest of the run, so
///   overspend is bounded by the single epoch in flight when the ceiling
///   is hit (plus trainer-level weight sync, which the controller cannot
///   veto).  The allowance planning exists to make this path unreachable
///   on a feasible budget.
pub struct BudgetController {
    budget: usize,
    epochs: usize,
    c_max: f32,
    /// next-epoch per-layer rate (the current plan)
    plan: Vec<f32>,
    spent: usize,
    epochs_observed: usize,
    /// latest measured non-layer (weight sync etc.) bytes per epoch
    overhead_est: f64,
    /// per-layer bytes/epoch estimate at rate 1
    full_est: Vec<f64>,
    /// budget exhausted: stop communicating instead of overspending
    halted: bool,
    last_rel_err: Option<f32>,
    violations: usize,
}

impl BudgetController {
    pub fn new(budget_bytes: usize, epochs: usize, layers: usize, c_max: f32) -> BudgetController {
        let c_max = c_max.max(1.0);
        BudgetController {
            budget: budget_bytes,
            epochs: epochs.max(1),
            c_max,
            plan: vec![c_max; layers.max(1)],
            spent: 0,
            epochs_observed: 0,
            overhead_est: 0.0,
            full_est: vec![0.0; layers.max(1)],
            halted: false,
            last_rel_err: None,
            violations: 0,
        }
    }

    /// True once the budget is exhausted and data traffic is halted.
    pub fn halted(&self) -> bool {
        self.halted
    }

    /// Total bytes observed so far.
    pub fn spent(&self) -> usize {
        self.spent
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Times the observed relative error rose epoch-over-epoch (each one
    /// triggered a forced rate back-off).
    pub fn violations(&self) -> usize {
        self.violations
    }

    /// The current per-layer plan (next epoch's rates).
    pub fn current_plan(&self) -> &[f32] {
        &self.plan
    }

    /// The configured starting (maximum) rate.
    pub fn c_max(&self) -> f32 {
        self.c_max
    }
}

impl RateController for BudgetController {
    fn label(&self) -> String {
        format!("budget-{}B", self.budget)
    }

    fn rate_for(&self, _epoch: usize, layer: usize, _kind: ChannelKind) -> Option<f32> {
        if self.halted {
            return None;
        }
        Some(self.plan[layer.min(self.plan.len() - 1)])
    }

    fn nominal_rate(&self, _epoch: usize) -> Option<f32> {
        if self.halted {
            return None;
        }
        // report the cheapest (= most communicative) layer's rate
        Some(self.plan.iter().copied().fold(f32::INFINITY, f32::min))
    }

    fn wants_feedback(&self) -> bool {
        true
    }

    fn observe(&mut self, fb: &Feedback) {
        self.spent += fb.total_bytes;
        self.epochs_observed += 1;
        let data = fb.data_bytes();
        self.overhead_est = fb.total_bytes.saturating_sub(data) as f64;
        let layers = self.plan.len();
        for l in 0..layers {
            let (bytes, rate) = fb
                .layers
                .get(l)
                .map(|m| (m.bytes, fb.rates.get(l).copied().flatten()))
                .unwrap_or((0, None));
            if let (true, Some(r)) = (bytes > 0, rate) {
                self.full_est[l] = bytes as f64 * f64::from(r);
            }
        }

        let done = self.epochs_observed;
        let remaining_epochs = self.epochs.saturating_sub(done);
        if remaining_epochs == 0 {
            return;
        }
        // hard ceiling: once the budget is actually gone, stop data
        // traffic instead of spending on at the frozen plan (overspend is
        // bounded by the one epoch in flight when the ceiling is hit; the
        // allowance planning below exists to never reach this point)
        self.halted = self.spent >= self.budget;
        if self.halted {
            return;
        }
        let remaining = self.budget.saturating_sub(self.spent) as f64;
        let avail = (remaining - self.overhead_est * remaining_epochs as f64).max(0.0);

        // quadratic ramp over the remaining epochs: weight(t) = (t+1)²
        let wsum: f64 = (done..self.epochs).map(|t| ((t + 1) * (t + 1)) as f64).sum();
        let this_w = ((done + 1) * (done + 1)) as f64;
        let allowance = if wsum > 0.0 { avail * this_w / wsum } else { 0.0 };

        let err_tot: f64 = fb.layers.iter().map(|l| f64::from(l.err_sq)).sum();
        let full_tot: f64 = self.full_est.iter().sum();
        if allowance > 0.0 && full_tot > 0.0 {
            for l in 0..layers {
                let byte_share = self.full_est[l] / full_tot;
                let err_share = if err_tot > 0.0 {
                    fb.layers.get(l).map(|m| f64::from(m.err_sq)).unwrap_or(0.0) / err_tot
                } else {
                    byte_share
                };
                let share = 0.5 * byte_share + 0.5 * err_share;
                let a_l = allowance * share;
                if a_l > 0.0 && self.full_est[l] > 0.0 {
                    let target = (self.full_est[l] / a_l) as f32;
                    self.plan[l] = target.clamp(1.0, self.plan[l]);
                }
            }
        }

        // Proposition 2 runtime guard: the error sequence must not grow
        if let (Some(rel), Some(last)) = (fb.rel_error(), self.last_rel_err) {
            if rel > last + 1e-6 {
                self.violations += 1;
                for p in self.plan.iter_mut() {
                    *p = (*p * 0.7).max(1.0);
                }
            }
        }
        if let Some(rel) = fb.rel_error() {
            self.last_rel_err = Some(rel);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::Scheduler;
    use super::*;

    fn fb(epoch: usize, total: usize, per_layer: &[(usize, f32, f32)], rates: &[f32]) -> Feedback {
        Feedback {
            epoch,
            total_bytes: total,
            layers: per_layer
                .iter()
                .map(|&(bytes, err_sq, sig_sq)| LayerFeedback { bytes, err_sq, sig_sq })
                .collect(),
            rates: rates.iter().map(|&r| Some(r)).collect(),
        }
    }

    #[test]
    fn open_loop_mirrors_comm_mode() {
        let c = OpenLoopController::new(CommMode::Full);
        assert_eq!(c.rate_for(3, 1, ChannelKind::Forward), Some(1.0));
        assert_eq!(c.label(), "full-comm");
        assert!(!c.wants_feedback());
        let n = OpenLoopController::new(CommMode::None);
        assert_eq!(n.rate_for(0, 0, ChannelKind::Backward), None);
        let s = OpenLoopController::new(CommMode::Compressed(Scheduler::Fixed { rate: 4.0 }));
        assert_eq!(s.rate_for(9, 2, ChannelKind::Forward), Some(4.0));
        assert_eq!(s.label(), "fixed-r4");
    }

    #[test]
    fn budget_starts_at_c_max_and_never_raises_rates() {
        let mut c = BudgetController::new(1_000_000, 10, 3, 128.0);
        assert_eq!(c.rate_for(0, 0, ChannelKind::Forward), Some(128.0));
        assert!(c.wants_feedback());
        let mut prev = vec![128.0f32; 3];
        for e in 0..9 {
            // generous budget: rates should descend towards 1
            c.observe(&fb(
                e,
                2_000,
                &[(600, 5.0, 10.0), (700, 3.0, 10.0), (700, 2.0, 10.0)],
                &prev,
            ));
            let cur: Vec<f32> = (0..3)
                .map(|l| c.rate_for(e + 1, l, ChannelKind::Forward).unwrap())
                .collect();
            for (l, (&p, &n)) in prev.iter().zip(&cur).enumerate() {
                assert!(n <= p + 1e-6, "layer {l} rate rose: {p} -> {n}");
                assert!(n >= 1.0);
            }
            prev = cur;
        }
        // with a huge budget the plan must have descended substantially
        assert!(prev.iter().all(|&r| r < 64.0), "plan {prev:?}");
    }

    #[test]
    fn budget_holds_high_rate_when_budget_tight() {
        let mut c = BudgetController::new(10_000, 100, 2, 64.0);
        // each epoch already spends 1/50 of the budget at rate 64: no room
        for e in 0..20 {
            c.observe(&fb(e, 200, &[(100, 1.0, 2.0), (100, 1.0, 2.0)], &[64.0, 64.0]));
        }
        let r = c.rate_for(20, 0, ChannelKind::Forward).unwrap();
        assert!(r > 32.0, "tight budget must keep compressing hard, got {r}");
    }

    #[test]
    fn error_rise_triggers_backoff_and_counts_violation() {
        // budget so tight the allowance never lowers the plan on its own:
        // the only way down is the error guard
        let mut c = BudgetController::new(10_000, 50, 1, 32.0);
        c.observe(&fb(0, 100, &[(100, 1.0, 10.0)], &[32.0]));
        let r1 = c.rate_for(1, 0, ChannelKind::Forward).unwrap();
        assert_eq!(c.violations(), 0);
        assert!((r1 - 32.0).abs() < 1e-5, "tight budget should hold c_max, got {r1}");
        // relative error quadruples: guard must back the plan off
        c.observe(&fb(1, 100, &[(100, 4.0, 10.0)], &[r1]));
        let r2 = c.rate_for(2, 0, ChannelKind::Forward).unwrap();
        assert_eq!(c.violations(), 1);
        assert!(r2 <= r1 * 0.7 + 1e-4, "{r1} -> {r2}");
    }

    #[test]
    fn exhausted_budget_halts_communication() {
        // infeasible budget: 100 epochs of 200 B against a 1 kB ceiling —
        // once spend crosses it, the controller must go silent instead of
        // spending at the frozen plan forever
        let mut c = BudgetController::new(1_000, 100, 2, 64.0);
        let mut halted_at = None;
        for e in 0..10 {
            if c.rate_for(e, 0, ChannelKind::Forward).is_none() {
                halted_at = Some(e);
                break;
            }
            c.observe(&fb(e, 200, &[(100, 1.0, 2.0), (100, 1.0, 2.0)], &[64.0, 64.0]));
        }
        let at = halted_at.expect("controller never halted on an infeasible budget");
        assert_eq!(at, 5, "spend crosses 1000 B after the 5th 200 B epoch");
        assert!(c.halted());
        assert_eq!(c.nominal_rate(at), None);
        assert_eq!(c.rate_for(at, 1, ChannelKind::Backward), None);
        assert!(c.spent() >= c.budget());
        // overspend is bounded by the epoch in flight at the crossing
        assert!(c.spent() <= c.budget() + 200);
    }

    #[test]
    fn spend_tracking_and_label() {
        let mut c = BudgetController::new(5_000, 4, 2, 128.0);
        c.observe(&fb(0, 1_200, &[(500, 1.0, 4.0), (500, 1.0, 4.0)], &[128.0, 128.0]));
        assert_eq!(c.spent(), 1_200);
        assert_eq!(c.budget(), 5_000);
        assert_eq!(c.label(), "budget-5000B");
        assert_eq!(c.current_plan().len(), 2);
    }

    #[test]
    fn ramp_concentrates_bytes_late() {
        // simulate a run where full-comm costs 1000 B/layer-epoch and the
        // budget is exactly half of full spend: the planned rate sequence
        // must descend monotonically to ~1 by the final epochs
        let epochs = 30;
        let mut c = BudgetController::new(15_000, epochs, 1, 128.0);
        let mut rates = vec![c.rate_for(0, 0, ChannelKind::Forward).unwrap()];
        let mut spent_model = 0usize;
        for e in 0..epochs - 1 {
            let r = *rates.last().unwrap();
            let bytes = (1000.0 / r).ceil() as usize;
            spent_model += bytes;
            c.observe(&fb(e, bytes, &[(bytes, 1.0 / r, 10.0)], &[r]));
            rates.push(c.rate_for(e + 1, 0, ChannelKind::Forward).unwrap());
        }
        assert!(rates.windows(2).all(|w| w[1] <= w[0] + 1e-6), "{rates:?}");
        let last = *rates.last().unwrap();
        assert!(last < 4.0, "final rate {last} should approach 1, rates {rates:?}");
        // ceil() rounding can leak ≤ 1 byte per epoch past the allowance
        assert!(spent_model <= 15_000 + epochs, "model overspent: {spent_model}");
    }
}
