//! The paper's compression mechanism (Appendix A): random element subset
//! with a shared key.
//!
//! "Which values of the vectors to communicate are chosen at random at the
//! encoder's end.  For the decoder to know which element of the vector
//! corresponds to the true values, a random key generator is shared a
//! priori.  The decoder simply places the values communicated in the
//! corresponding position and sets a 0 on the rest."
//!
//! Backward pass: the gradient w.r.t. the *sent* activation is the
//! received cotangent masked by the same index set, so the coordinator
//! compresses the error message **with the same key** — identical to
//! back-propagating through the (fixed-mask) compression routine.

use super::{kept_count, Codec, Compressor, Payload};
use crate::util::Rng;

pub struct RandomSubsetCompressor;

impl RandomSubsetCompressor {
    /// The shared-seed index set both endpoints derive.
    pub fn indices(n: usize, rate: f32, key: u64) -> Vec<u32> {
        let m = kept_count(n, rate);
        Rng::new(key).sample_indices(n, m)
    }
}

impl Compressor for RandomSubsetCompressor {
    fn name(&self) -> &'static str {
        "random-subset"
    }

    fn compress(&self, x: &[f32], rate: f32, key: u64) -> Payload {
        // r = 1 keeps everything: skip the permutation entirely (hot path
        // for FullComm and the late epochs of every VARCO schedule).
        if rate <= 1.0 {
            return Payload { n: x.len(), values: x.to_vec(), indices: None, key, side: vec![], codec: Codec::Keyed };
        }
        let idx = Self::indices(x.len(), rate, key);
        let values = idx.iter().map(|&i| x[i as usize]).collect();
        Payload { n: x.len(), values, indices: None, key, side: vec![], codec: Codec::Keyed }
    }

    fn decompress(&self, payload: &Payload, out: &mut [f32]) {
        assert_eq!(out.len(), payload.n);
        if payload.is_dropped() {
            // lost on the wire: the mechanism's missing-value semantics
            out.fill(0.0);
            return;
        }
        let m = payload.values.len();
        if m == payload.n {
            // lossless fast path (rate 1)
            out.copy_from_slice(&payload.values);
            return;
        }
        out.fill(0.0);
        // re-derive the index set from the shared key; use the payload
        // length directly (kept_count rounding already happened encode-side)
        let idx = Rng::new(payload.key).sample_indices(payload.n, m.min(payload.n));
        for (&i, &v) in idx.iter().zip(&payload.values) {
            out[i as usize] = v;
        }
    }

    /// Masking channel: the error is exactly the dropped mass,
    /// `Σ x² − Σ values²` — no reconstruction needed.
    fn channel_error(&self, x: &[f32], payload: &Payload) -> (f32, f32) {
        let total: f32 = x.iter().map(|v| v * v).sum();
        let kept: f32 = payload.values.iter().map(|v| v * v).sum();
        ((total - kept).max(0.0), total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn payload(n: usize, rate: f32, key: u64) -> (Vec<f32>, Payload) {
        let mut rng = Rng::new(99);
        let x: Vec<f32> = (0..n).map(|_| rng.next_normal()).collect();
        let p = RandomSubsetCompressor.compress(&x, rate, key);
        (x, p)
    }

    #[test]
    fn roundtrip_is_masked_identity() {
        let (x, p) = payload(200, 4.0, 7);
        let mut out = vec![0.0; 200];
        RandomSubsetCompressor.decompress(&p, &mut out);
        let idx = RandomSubsetCompressor::indices(200, 4.0, 7);
        let kept: std::collections::HashSet<u32> = idx.into_iter().collect();
        for i in 0..200 {
            if kept.contains(&(i as u32)) {
                assert_eq!(out[i], x[i]);
            } else {
                assert_eq!(out[i], 0.0);
            }
        }
    }

    #[test]
    fn rate_one_lossless() {
        let (x, p) = payload(64, 1.0, 3);
        assert_eq!(p.values.len(), 64);
        let mut out = vec![0.0; 64];
        RandomSubsetCompressor.decompress(&p, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn wire_size_is_ceil_n_over_r_plus_header() {
        // kept values dominate the wire cost; the fixed header (length
        // prefix + codec tag + n + key + empty side + m) rides on top
        let (_, p) = payload(100, 3.0, 1);
        assert_eq!(p.values.len(), 34);
        let header = p.wire_bytes() - 4 * 34;
        assert!(header < 24, "header {header}");
        let (_, p) = payload(100, 128.0, 1);
        assert_eq!(p.values.len(), 1);
        assert_eq!(p.wire_bytes(), p.encode().len());
    }

    #[test]
    fn both_endpoints_agree_on_indices() {
        let a = RandomSubsetCompressor::indices(1000, 8.0, 42);
        let b = RandomSubsetCompressor::indices(1000, 8.0, 42);
        assert_eq!(a, b);
        let c = RandomSubsetCompressor::indices(1000, 8.0, 43);
        assert_ne!(a, c);
    }

    #[test]
    fn error_mass_equals_dropped_mass() {
        // E||x̃-x||² = Σ_{dropped} x_i² (Definition 1's ε characterization)
        let (x, p) = payload(500, 5.0, 11);
        let mut out = vec![0.0; 500];
        RandomSubsetCompressor.decompress(&p, &mut out);
        let err: f32 = x.iter().zip(&out).map(|(a, b)| (a - b).powi(2)).sum();
        let total: f32 = x.iter().map(|a| a * a).sum();
        let kept: f32 = out.iter().map(|a| a * a).sum();
        assert!((err - (total - kept)).abs() < 1e-3);
    }

    #[test]
    fn empty_payload_roundtrip() {
        let p = RandomSubsetCompressor.compress(&[], 2.0, 0);
        assert!(p.values.is_empty());
        let mut out = vec![];
        RandomSubsetCompressor.decompress(&p, &mut out);
    }

    #[test]
    fn channel_error_override_matches_reconstruction() {
        let (x, p) = payload(300, 6.0, 21);
        let mut out = vec![0.0; 300];
        RandomSubsetCompressor.decompress(&p, &mut out);
        let want: f32 = x.iter().zip(&out).map(|(a, b)| (a - b) * (a - b)).sum();
        let (got, sig) = RandomSubsetCompressor.channel_error(&x, &p);
        assert!((got - want).abs() <= 1e-3 * (1.0 + want), "{got} vs {want}");
        let want_sig: f32 = x.iter().map(|v| v * v).sum();
        assert_eq!(sig, want_sig);
    }
}
