//! Uniform scalar quantization baseline (the "quantization" family of
//! related work, §Related Work).  Rate r maps to b = 32/r bits per
//! element; wire cost is n*b/32 float-equivalents plus the (min, max)
//! side channel.  Lossy but full-support (no zeros), so its error profile
//! differs from subset masking — useful contrast in the ablation bench.

use super::{Compressor, Payload};

pub struct QuantizeCompressor;

fn bits_for_rate(rate: f32) -> u32 {
    ((32.0 / rate).round() as u32).clamp(1, 32)
}

impl Compressor for QuantizeCompressor {
    fn name(&self) -> &'static str {
        "quantize"
    }

    fn compress(&self, x: &[f32], rate: f32, key: u64) -> Payload {
        let bits = bits_for_rate(rate);
        if x.is_empty() {
            return Payload { n: 0, values: vec![], indices: None, key, side: vec![0.0, 0.0, bits as f32], wire_override: None };
        }
        // single fused pass over x for both extrema (was two separate folds)
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in x {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let levels = ((1u64 << bits) - 1) as f32;
        let scale = if hi > lo { levels / (hi - lo) } else { 0.0 };
        // Quantized codes stay f32 in simulation; the wire accounting
        // charges `bits` per element + the (min, max) side channel.
        let values: Vec<f32> = x.iter().map(|&v| ((v - lo) * scale).round()).collect();
        let wire = (x.len() * bits as usize).div_ceil(32) + 2;
        Payload {
            n: x.len(),
            values,
            indices: None,
            key,
            side: vec![lo, hi, bits as f32],
            wire_override: Some(wire),
        }
    }

    fn decompress(&self, payload: &Payload, out: &mut [f32]) {
        assert_eq!(out.len(), payload.n);
        let [lo, hi, bits] = payload.side[..] else { panic!("quantize side channel") };
        let levels = ((1u64 << bits as u32) - 1) as f32;
        let step = if levels > 0.0 { (hi - lo) / levels } else { 0.0 };
        for (o, &c) in out.iter_mut().zip(&payload.values) {
            *o = lo + c * step;
        }
    }
}



#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_within_step() {
        let x: Vec<f32> = (0..100).map(|i| (i as f32) / 10.0 - 5.0).collect();
        let p = QuantizeCompressor.compress(&x, 4.0, 0); // 8 bits
        let mut out = vec![0.0; 100];
        QuantizeCompressor.decompress(&p, &mut out);
        let step = 10.0 / 255.0;
        for (a, b) in x.iter().zip(&out) {
            assert!((a - b).abs() <= step / 2.0 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn bits_mapping() {
        assert_eq!(bits_for_rate(1.0), 32);
        assert_eq!(bits_for_rate(4.0), 8);
        assert_eq!(bits_for_rate(32.0), 1);
        assert_eq!(bits_for_rate(128.0), 1);
    }

    #[test]
    fn wire_cost_scales_with_bits() {
        let x = vec![1.0; 64];
        let p = QuantizeCompressor.compress(&x, 4.0, 0); // 8 bits
        assert_eq!(p.wire_floats(), 16 + 2);
    }

    #[test]
    fn constant_signal_exact() {
        let x = vec![2.5; 10];
        let p = QuantizeCompressor.compress(&x, 8.0, 0);
        let mut out = vec![0.0; 10];
        QuantizeCompressor.decompress(&p, &mut out);
        assert_eq!(out, x);
    }
}
