//! Uniform scalar quantization baseline (the "quantization" family of
//! related work, §Related Work).  Rate r maps to b = 32/r bits per
//! element; the codes stay f32 in simulation but the wire codec bit-packs
//! them, so `wire_bytes` is `ceil(n·b/8)` plus the (min, max) side
//! channel and header.  Lossy but full-support (no zeros), so its error
//! profile differs from subset masking — useful contrast in the ablation
//! bench.

use super::{Codec, Compressor, Payload};

pub struct QuantizeCompressor;

fn bits_for_rate(rate: f32) -> u32 {
    ((32.0 / rate).round() as u32).clamp(1, 32)
}

impl Compressor for QuantizeCompressor {
    fn name(&self) -> &'static str {
        "quantize"
    }

    fn compress(&self, x: &[f32], rate: f32, key: u64) -> Payload {
        let bits = bits_for_rate(rate) as u8;
        let codec = Codec::Quantized { bits };
        if x.is_empty() {
            return Payload { n: 0, values: vec![], indices: None, key, side: vec![0.0, 0.0], codec };
        }
        // single fused pass over x for both extrema (was two separate folds)
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in x {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let levels = ((1u64 << bits) - 1) as f32;
        let scale = if hi > lo { levels / (hi - lo) } else { 0.0 };
        let values: Vec<f32> = x.iter().map(|&v| ((v - lo) * scale).round()).collect();
        Payload { n: x.len(), values, indices: None, key, side: vec![lo, hi], codec }
    }

    fn decompress(&self, payload: &Payload, out: &mut [f32]) {
        assert_eq!(out.len(), payload.n);
        if payload.is_dropped() {
            // lost on the wire: exact zeros, NOT `min + 0·step` (the wrong
            // answer zeroed codes would decode to)
            out.fill(0.0);
            return;
        }
        let Codec::Quantized { bits } = payload.codec else { panic!("quantize payload codec") };
        let [lo, hi] = payload.side[..] else { panic!("quantize side channel") };
        let levels = ((1u64 << bits) - 1) as f32;
        let step = if levels > 0.0 { (hi - lo) / levels } else { 0.0 };
        for (o, &c) in out.iter_mut().zip(&payload.values) {
            *o = lo + c * step;
        }
    }

    /// One fused pass: reconstruct each element analytically, diff, and
    /// accumulate the signal mass alongside.
    fn channel_error(&self, x: &[f32], payload: &Payload) -> (f32, f32) {
        let Codec::Quantized { bits } = payload.codec else { panic!("quantize payload codec") };
        let [lo, hi] = payload.side[..] else { panic!("quantize side channel") };
        let levels = ((1u64 << bits) - 1) as f32;
        let step = if levels > 0.0 { (hi - lo) / levels } else { 0.0 };
        let (mut err, mut sig) = (0.0f32, 0.0f32);
        for (&v, &c) in x.iter().zip(&payload.values) {
            let d = v - (lo + c * step);
            err += d * d;
            sig += v * v;
        }
        (err, sig)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_within_step() {
        let x: Vec<f32> = (0..100).map(|i| (i as f32) / 10.0 - 5.0).collect();
        let p = QuantizeCompressor.compress(&x, 4.0, 0); // 8 bits
        let mut out = vec![0.0; 100];
        QuantizeCompressor.decompress(&p, &mut out);
        let step = 10.0 / 255.0;
        for (a, b) in x.iter().zip(&out) {
            assert!((a - b).abs() <= step / 2.0 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn bits_mapping() {
        assert_eq!(bits_for_rate(1.0), 32);
        assert_eq!(bits_for_rate(4.0), 8);
        assert_eq!(bits_for_rate(32.0), 1);
        assert_eq!(bits_for_rate(128.0), 1);
    }

    #[test]
    fn wire_cost_scales_with_bits() {
        let x = vec![1.0; 64];
        let p8 = QuantizeCompressor.compress(&x, 4.0, 0); // 8 bits -> 64 code bytes
        let p1 = QuantizeCompressor.compress(&x, 32.0, 0); // 1 bit -> 8 code bytes
        assert_eq!(p8.wire_bytes() - p1.wire_bytes(), 64 - 8);
        assert_eq!(p8.wire_bytes(), p8.encode().len());
        assert_eq!(p1.wire_bytes(), p1.encode().len());
    }

    #[test]
    fn constant_signal_exact() {
        let x = vec![2.5; 10];
        let p = QuantizeCompressor.compress(&x, 8.0, 0);
        let mut out = vec![0.0; 10];
        QuantizeCompressor.decompress(&p, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn channel_error_matches_reconstruction() {
        let x: Vec<f32> = (0..128).map(|i| ((i * 13 % 31) as f32) * 0.37 - 4.0).collect();
        let p = QuantizeCompressor.compress(&x, 8.0, 0);
        let mut out = vec![0.0; 128];
        QuantizeCompressor.decompress(&p, &mut out);
        let want: f32 = x.iter().zip(&out).map(|(a, b)| (a - b) * (a - b)).sum();
        let (err, sig) = QuantizeCompressor.channel_error(&x, &p);
        assert!((err - want).abs() <= 1e-5 * (1.0 + want));
        assert!(sig > 0.0);
    }
}
