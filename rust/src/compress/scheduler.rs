//! Compression-rate schedulers (paper §IV + Appendix A, eq. (8)).
//!
//! A scheduler maps the epoch t to a compression rate r(t) >= 1, strictly
//! non-increasing (Proposition 2's condition: the compression error must
//! decrease every step).  The paper's experiments use the linear family
//!
//! ```text
//! c(k) = clamp(c_max - a * (c_max - c_min) / K * k,  c_min, c_max)
//! ```
//!
//! with slopes a ∈ {2..7}, c_max = 128, c_min = 1.

use crate::Result;

/// How a run communicates.  FullComm / NoComm are the paper's baselines.
#[derive(Clone, Debug, PartialEq)]
pub enum CommMode {
    /// exchange uncompressed boundary activations every layer
    Full,
    /// never exchange; aggregate over local neighbors only
    None,
    /// exchange compressed with the rate given by the scheduler
    Compressed(Scheduler),
}

impl CommMode {
    /// Rate at epoch t; `None` means "do not communicate at all".
    pub fn rate_at(&self, epoch: usize) -> Option<f32> {
        match self {
            CommMode::Full => Some(1.0),
            CommMode::None => None,
            CommMode::Compressed(s) => Some(s.rate_at(epoch)),
        }
    }

    pub fn label(&self) -> String {
        match self {
            CommMode::Full => "full-comm".into(),
            CommMode::None => "no-comm".into(),
            CommMode::Compressed(s) => s.label(),
        }
    }
}

/// How a closed-loop budget run assigns rates across directed
/// (sender, receiver) links — the optional trailing token of a
/// `budget:BYTES[:CMAX][:uniform|linkaware]` comm spec.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RateAlloc {
    /// one rate per (epoch, layer), shared by every link (the paper's
    /// variable-rate scheme)
    #[default]
    Uniform,
    /// per-(sender, receiver) water-filling on top of the uniform plan:
    /// hot links compress harder so bottleneck seconds shrink at equal
    /// total bytes ([`LinkAwareBudgetController`](super::LinkAwareBudgetController))
    LinkAware,
}

impl RateAlloc {
    pub fn parse(s: &str) -> Result<RateAlloc> {
        match s {
            "uniform" => Ok(RateAlloc::Uniform),
            "linkaware" => Ok(RateAlloc::LinkAware),
            _ => anyhow::bail!("bad rate allocation {s:?}; use uniform | linkaware"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RateAlloc::Uniform => "uniform",
            RateAlloc::LinkAware => "linkaware",
        }
    }
}

/// Rate schedulers; all clamp to [c_min, c_max] and are non-increasing.
#[derive(Clone, Debug, PartialEq)]
pub enum Scheduler {
    /// constant rate (Proposition 1's regime)
    Fixed { rate: f32 },
    /// paper eq. (8): linear descent with slope `a` over `total` epochs
    Linear { slope: f32, c_max: f32, c_min: f32, total: usize },
    /// geometric descent from c_max to c_min over `total` epochs
    Exponential { c_max: f32, c_min: f32, total: usize },
    /// halve every `every` epochs from c_max, floor at c_min
    Step { c_max: f32, c_min: f32, every: usize, factor: f32 },
}

impl Scheduler {
    /// The paper's experimental configuration: linear, c_max=128, c_min=1.
    pub fn paper_linear(slope: f32, total: usize) -> Scheduler {
        Scheduler::Linear { slope, c_max: 128.0, c_min: 1.0, total }
    }

    pub fn rate_at(&self, epoch: usize) -> f32 {
        match *self {
            Scheduler::Fixed { rate } => rate.max(1.0),
            Scheduler::Linear { slope, c_max, c_min, total } => {
                let k = epoch as f32;
                let t = total.max(1) as f32;
                (c_max - slope * (c_max - c_min) / t * k).clamp(c_min.max(1.0), c_max)
            }
            Scheduler::Exponential { c_max, c_min, total } => {
                let t = (total.max(2) - 1) as f32;
                let frac = (epoch as f32 / t).min(1.0);
                let lo = c_min.max(1.0);
                (c_max * (lo / c_max).powf(frac)).clamp(lo, c_max)
            }
            Scheduler::Step { c_max, c_min, every, factor } => {
                let steps = epoch / every.max(1);
                (c_max / factor.max(1.0).powi(steps as i32)).clamp(c_min.max(1.0), c_max)
            }
        }
    }

    pub fn label(&self) -> String {
        match *self {
            Scheduler::Fixed { rate } => format!("fixed-r{rate}"),
            Scheduler::Linear { slope, .. } => format!("varco-linear-s{slope}"),
            Scheduler::Exponential { .. } => "varco-exp".into(),
            Scheduler::Step { every, factor, .. } => format!("varco-step-{every}x{factor}"),
        }
    }

    /// Reject configurations that violate the paper's scheduler contract
    /// (rates must be >= 1 and non-increasing in the epoch, Prop. 2) —
    /// previously e.g. `fixed:0.5` or `linear:-3` were clamped silently.
    pub fn validate(&self) -> Result<()> {
        match *self {
            Scheduler::Fixed { rate } => {
                anyhow::ensure!(
                    rate.is_finite() && rate >= 1.0,
                    "fixed scheduler rate {rate} violates the rate >= 1 requirement"
                );
            }
            Scheduler::Linear { slope, c_max, c_min, total } => {
                anyhow::ensure!(
                    slope.is_finite() && slope > 0.0,
                    "linear scheduler slope {slope} must be > 0 (rates must be non-increasing)"
                );
                anyhow::ensure!(c_min >= 1.0, "linear scheduler c_min {c_min} must be >= 1");
                anyhow::ensure!(
                    c_max >= c_min,
                    "linear scheduler c_max {c_max} must be >= c_min {c_min}"
                );
                anyhow::ensure!(total >= 1, "linear scheduler needs total >= 1 epochs");
            }
            Scheduler::Exponential { c_max, c_min, total } => {
                anyhow::ensure!(c_min >= 1.0, "exp scheduler c_min {c_min} must be >= 1");
                anyhow::ensure!(
                    c_max >= c_min,
                    "exp scheduler c_max {c_max} must be >= c_min {c_min}"
                );
                anyhow::ensure!(total >= 1, "exp scheduler needs total >= 1 epochs");
            }
            Scheduler::Step { c_max, c_min, every, factor } => {
                anyhow::ensure!(
                    factor.is_finite() && factor > 1.0,
                    "step scheduler factor {factor} must be > 1 (rates must decrease)"
                );
                anyhow::ensure!(every >= 1, "step scheduler interval must be >= 1");
                anyhow::ensure!(c_min >= 1.0, "step scheduler c_min {c_min} must be >= 1");
                anyhow::ensure!(
                    c_max >= c_min,
                    "step scheduler c_max {c_max} must be >= c_min {c_min}"
                );
            }
        }
        Ok(())
    }

    /// Parse config strings like "fixed:4", "linear:5", "exp", "step:30:2".
    /// Specs that violate the non-increasing / >= 1 contract are rejected.
    pub fn parse(s: &str, total_epochs: usize) -> Result<Scheduler> {
        let parts: Vec<&str> = s.split(':').collect();
        let sched = match parts.as_slice() {
            ["fixed", r] => Scheduler::Fixed { rate: r.parse()? },
            ["linear", a] => Scheduler::paper_linear(a.parse()?, total_epochs),
            ["exp"] => Scheduler::Exponential { c_max: 128.0, c_min: 1.0, total: total_epochs },
            ["step", every, factor] => Scheduler::Step {
                c_max: 128.0,
                c_min: 1.0,
                every: every.parse()?,
                factor: factor.parse()?,
            },
            _ => anyhow::bail!("bad scheduler spec {s:?}; use fixed:R | linear:A | exp | step:E:F"),
        };
        sched.validate()?;
        Ok(sched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_non_increasing(s: &Scheduler, total: usize) {
        let mut prev = f32::INFINITY;
        for t in 0..total {
            let r = s.rate_at(t);
            assert!(r >= 1.0, "{s:?} rate {r} < 1 at {t}");
            assert!(r <= prev + 1e-6, "{s:?} increased at {t}: {prev} -> {r}");
            prev = r;
        }
    }

    #[test]
    fn all_schedulers_non_increasing_and_clamped() {
        let total = 300;
        for s in [
            Scheduler::Fixed { rate: 4.0 },
            Scheduler::paper_linear(5.0, total),
            Scheduler::Exponential { c_max: 128.0, c_min: 1.0, total },
            Scheduler::Step { c_max: 128.0, c_min: 1.0, every: 25, factor: 2.0 },
        ] {
            assert_non_increasing(&s, total);
        }
    }

    #[test]
    fn paper_linear_hits_floor_at_total_over_slope() {
        let s = Scheduler::paper_linear(5.0, 300);
        assert_eq!(s.rate_at(0), 128.0);
        // reaches c_min ≈ at k = K/a = 60 (128 - 5*127/300*60 = 1.0)
        assert!(s.rate_at(60) <= 1.5);
        assert_eq!(s.rate_at(100), 1.0);
        assert_eq!(s.rate_at(299), 1.0);
    }

    #[test]
    fn larger_slope_descends_faster() {
        let s2 = Scheduler::paper_linear(2.0, 300);
        let s7 = Scheduler::paper_linear(7.0, 300);
        assert!(s7.rate_at(30) < s2.rate_at(30));
    }

    #[test]
    fn exponential_endpoints() {
        let s = Scheduler::Exponential { c_max: 128.0, c_min: 1.0, total: 100 };
        assert!((s.rate_at(0) - 128.0).abs() < 1e-3);
        assert!((s.rate_at(99) - 1.0).abs() < 1e-3);
    }

    #[test]
    fn step_halves() {
        let s = Scheduler::Step { c_max: 16.0, c_min: 1.0, every: 10, factor: 2.0 };
        assert_eq!(s.rate_at(0), 16.0);
        assert_eq!(s.rate_at(10), 8.0);
        assert_eq!(s.rate_at(45), 1.0);
    }

    #[test]
    fn comm_mode_rates() {
        assert_eq!(CommMode::Full.rate_at(5), Some(1.0));
        assert_eq!(CommMode::None.rate_at(5), None);
        let m = CommMode::Compressed(Scheduler::Fixed { rate: 2.0 });
        assert_eq!(m.rate_at(5), Some(2.0));
    }

    #[test]
    fn parse_specs() {
        assert_eq!(
            Scheduler::parse("fixed:4", 10).unwrap(),
            Scheduler::Fixed { rate: 4.0 }
        );
        assert!(matches!(
            Scheduler::parse("linear:5", 100).unwrap(),
            Scheduler::Linear { total: 100, .. }
        ));
        assert!(Scheduler::parse("bogus", 10).is_err());
    }

    #[test]
    fn parse_rejects_contract_violations() {
        // sub-one fixed rate: silently clamped before, now an error
        let err = Scheduler::parse("fixed:0.5", 10).unwrap_err().to_string();
        assert!(err.contains(">= 1"), "{err}");
        // negative slope would make the rate schedule non-decreasing
        let err = Scheduler::parse("linear:-3", 100).unwrap_err().to_string();
        assert!(err.contains("non-increasing"), "{err}");
        assert!(Scheduler::parse("linear:0", 100).is_err());
        // step factor must strictly decrease the rate
        assert!(Scheduler::parse("step:10:1", 100).is_err());
        assert!(Scheduler::parse("step:0:2", 100).is_err());
        // valid specs still parse
        assert!(Scheduler::parse("fixed:1", 10).is_ok());
        assert!(Scheduler::parse("linear:5", 100).is_ok());
        assert!(Scheduler::parse("exp", 100).is_ok());
        assert!(Scheduler::parse("step:10:2", 100).is_ok());
    }

    #[test]
    fn validate_checks_direct_constructions() {
        assert!(Scheduler::Fixed { rate: 0.5 }.validate().is_err());
        assert!(Scheduler::Fixed { rate: f32::NAN }.validate().is_err());
        assert!(Scheduler::Fixed { rate: 4.0 }.validate().is_ok());
        assert!(Scheduler::Linear { slope: 5.0, c_max: 0.5, c_min: 0.1, total: 10 }
            .validate()
            .is_err());
        assert!(Scheduler::Linear { slope: 5.0, c_max: 64.0, c_min: 1.0, total: 10 }
            .validate()
            .is_ok());
        assert!(Scheduler::Exponential { c_max: 1.0, c_min: 2.0, total: 10 }.validate().is_err());
        assert!(Scheduler::Step { c_max: 16.0, c_min: 1.0, every: 5, factor: 2.0 }
            .validate()
            .is_ok());
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(CommMode::Full.label(), "full-comm");
        assert_eq!(Scheduler::paper_linear(5.0, 10).label(), "varco-linear-s5");
    }
}
