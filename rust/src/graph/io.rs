//! Binary dataset/graph serialization (little-endian, versioned header).
//!
//! Lets expensive dataset builds be cached on disk and shared between the
//! experiment harnesses (`varco dataset build` / `--cache`).

use super::{Csr, Dataset, Split};
use crate::tensor::Matrix;
use crate::Result;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"VARCODS\x01";

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_u32s(w: &mut impl Write, xs: &[u32]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_u32s(r: &mut impl Read) -> Result<Vec<u32>> {
    let n = read_u64(r)? as usize;
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_f32s(r: &mut impl Read) -> Result<Vec<f32>> {
    let n = read_u64(r)? as usize;
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
}

fn write_bools(w: &mut impl Write, xs: &[bool]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    let bytes: Vec<u8> = xs.iter().map(|&b| b as u8).collect();
    w.write_all(&bytes)?;
    Ok(())
}

fn read_bools(r: &mut impl Read) -> Result<Vec<bool>> {
    let n = read_u64(r)? as usize;
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(buf.into_iter().map(|b| b != 0).collect())
}

pub fn save_dataset(ds: &Dataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    let name = ds.name.as_bytes();
    write_u64(&mut w, name.len() as u64)?;
    w.write_all(name)?;
    write_u64(&mut w, ds.graph.n as u64)?;
    write_u64(&mut w, ds.classes as u64)?;
    // indptr as u64
    write_u64(&mut w, ds.graph.indptr.len() as u64)?;
    for &p in &ds.graph.indptr {
        w.write_all(&p.to_le_bytes())?;
    }
    write_u32s(&mut w, &ds.graph.indices)?;
    write_u64(&mut w, ds.features.rows as u64)?;
    write_u64(&mut w, ds.features.cols as u64)?;
    write_f32s(&mut w, &ds.features.data)?;
    write_u32s(&mut w, &ds.labels)?;
    write_bools(&mut w, &ds.split.train)?;
    write_bools(&mut w, &ds.split.val)?;
    write_bools(&mut w, &ds.split.test)?;
    w.flush()?;
    Ok(())
}

pub fn load_dataset(path: &Path) -> Result<Dataset> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "bad magic in {path:?}: not a varco dataset");
    let name_len = read_u64(&mut r)? as usize;
    let mut name_buf = vec![0u8; name_len];
    r.read_exact(&mut name_buf)?;
    let n = read_u64(&mut r)? as usize;
    let classes = read_u64(&mut r)? as usize;
    let indptr_len = read_u64(&mut r)? as usize;
    let mut indptr = Vec::with_capacity(indptr_len);
    for _ in 0..indptr_len {
        indptr.push(read_u64(&mut r)?);
    }
    let indices = read_u32s(&mut r)?;
    let rows = read_u64(&mut r)? as usize;
    let cols = read_u64(&mut r)? as usize;
    let data = read_f32s(&mut r)?;
    let labels = read_u32s(&mut r)?;
    let train = read_bools(&mut r)?;
    let val = read_bools(&mut r)?;
    let test = read_bools(&mut r)?;
    let ds = Dataset {
        name: String::from_utf8(name_buf)?,
        graph: Csr { n, indptr, indices },
        features: Matrix::from_vec(rows, cols, data),
        labels,
        classes,
        split: Split { train, val, test },
    };
    ds.validate()?;
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_preserves_everything() {
        let ds = Dataset::load("karate-like", 0, 5).unwrap();
        let dir = crate::util::testing::TempDir::new().unwrap();
        let path = dir.path().join("ds.bin");
        save_dataset(&ds, &path).unwrap();
        let back = load_dataset(&path).unwrap();
        assert_eq!(ds.name, back.name);
        assert_eq!(ds.graph, back.graph);
        assert_eq!(ds.features, back.features);
        assert_eq!(ds.labels, back.labels);
        assert_eq!(ds.split, back.split);
        assert_eq!(ds.classes, back.classes);
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = crate::util::testing::TempDir::new().unwrap();
        let path = dir.path().join("junk.bin");
        std::fs::write(&path, b"notadataset....").unwrap();
        let err = load_dataset(&path).unwrap_err();
        assert!(err.to_string().contains("bad magic"));
    }

    #[test]
    fn truncated_file_errors_cleanly() {
        let ds = Dataset::load("karate-like", 0, 5).unwrap();
        let dir = crate::util::testing::TempDir::new().unwrap();
        let path = dir.path().join("ds.bin");
        save_dataset(&ds, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load_dataset(&path).is_err());
    }
}
