//! Binary dataset/graph serialization.
//!
//! Two formats live here:
//!
//!  * **v1 single-file** (`save_dataset` / `load_dataset`): the original
//!    little-endian blob behind `varco dataset build` / `--cache`.  The
//!    loader is hardened: every header-declared section length is checked
//!    against the bytes actually remaining in the file *before* anything
//!    is allocated, so a corrupt or truncated header produces a clear
//!    error instead of an OOM-sized allocation.
//!
//!  * **v2 sharded directory** (`write_shards` / [`ShardManifest`]): the
//!    out-of-core layout behind `store = mmap`.  Headerless raw
//!    little-endian segments — `indptr.bin` ((n+1) x u64), `indices.bin`
//!    (u32), `labels.bin` (u32), `split.bin` (one mask byte per node) —
//!    plus fixed-stride feature shards `features_NNNN.bin`
//!    (`rows_per_shard` rows of `f_in` f32s each; the last shard may be
//!    short).  `manifest.json` records sizes and per-file FNV-1a hashes;
//!    [`MmapStore::open`](crate::graph::store::MmapStore::open) verifies
//!    both before mapping anything, and the manifest's combined content
//!    hash joins the distributed admission hash so tcp workers can only
//!    join a driver whose shards are byte-identical to theirs.

use super::{Csr, Dataset, Split};
use crate::tensor::Matrix;
use crate::util::Json;
use crate::Result;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"VARCODS\x01";

/// Streaming FNV-1a (64-bit) — the repo's standing content-hash primitive.
pub struct Fnv(u64);

impl Fnv {
    pub fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv {
    fn default() -> Fnv {
        Fnv::new()
    }
}

/// Reader that tracks how many file bytes remain, so declared section
/// lengths can be budget-checked before allocation.
struct Bounded<R> {
    r: R,
    left: u64,
}

impl<R: Read> Bounded<R> {
    fn take(&mut self, n: u64, what: &str) -> Result<()> {
        anyhow::ensure!(
            n <= self.left,
            "corrupt dataset: {what} declares {n} bytes but only {} remain in the file",
            self.left
        );
        self.left -= n;
        Ok(())
    }

    fn exact(&mut self, buf: &mut [u8], what: &str) -> Result<()> {
        self.take(buf.len() as u64, what)?;
        self.r.read_exact(buf)?;
        Ok(())
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let mut b = [0u8; 8];
        self.exact(&mut b, what)?;
        Ok(u64::from_le_bytes(b))
    }

    /// Read a length-prefixed section of `n * width`-byte items.
    fn section(&mut self, width: u64, what: &str) -> Result<Vec<u8>> {
        let n = self.u64(what)?;
        let bytes = n
            .checked_mul(width)
            .ok_or_else(|| anyhow::anyhow!("corrupt dataset: {what} length {n} overflows"))?;
        self.take(bytes, what)?;
        let mut buf = vec![0u8; bytes as usize];
        self.r.read_exact(&mut buf)?;
        Ok(buf)
    }

    fn u64s(&mut self, what: &str) -> Result<Vec<u64>> {
        let buf = self.section(8, what)?;
        Ok(buf.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn u32s(&mut self, what: &str) -> Result<Vec<u32>> {
        let buf = self.section(4, what)?;
        Ok(buf.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn f32s(&mut self, what: &str) -> Result<Vec<f32>> {
        let buf = self.section(4, what)?;
        Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn bools(&mut self, what: &str) -> Result<Vec<bool>> {
        let buf = self.section(1, what)?;
        Ok(buf.into_iter().map(|b| b != 0).collect())
    }
}

fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

fn write_u32s(w: &mut impl Write, xs: &[u32]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn write_bools(w: &mut impl Write, xs: &[bool]) -> Result<()> {
    write_u64(w, xs.len() as u64)?;
    let bytes: Vec<u8> = xs.iter().map(|&b| b as u8).collect();
    w.write_all(&bytes)?;
    Ok(())
}

pub fn save_dataset(ds: &Dataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    let name = ds.name.as_bytes();
    write_u64(&mut w, name.len() as u64)?;
    w.write_all(name)?;
    write_u64(&mut w, ds.graph.n as u64)?;
    write_u64(&mut w, ds.classes as u64)?;
    // indptr as u64
    write_u64(&mut w, ds.graph.indptr.len() as u64)?;
    for &p in &ds.graph.indptr {
        w.write_all(&p.to_le_bytes())?;
    }
    write_u32s(&mut w, &ds.graph.indices)?;
    write_u64(&mut w, ds.features.rows as u64)?;
    write_u64(&mut w, ds.features.cols as u64)?;
    write_f32s(&mut w, &ds.features.data)?;
    write_u32s(&mut w, &ds.labels)?;
    write_bools(&mut w, &ds.split.train)?;
    write_bools(&mut w, &ds.split.val)?;
    write_bools(&mut w, &ds.split.test)?;
    w.flush()?;
    Ok(())
}

pub fn load_dataset(path: &Path) -> Result<Dataset> {
    let file = std::fs::File::open(path)?;
    let file_len = file.metadata()?.len();
    let mut r = Bounded { r: BufReader::new(file), left: file_len };
    let mut magic = [0u8; 8];
    r.exact(&mut magic, "magic")?;
    anyhow::ensure!(&magic == MAGIC, "bad magic in {path:?}: not a varco dataset");
    let name_len = r.u64("name length")?;
    r.take(name_len, "name")?;
    let mut name_buf = vec![0u8; name_len as usize];
    r.r.read_exact(&mut name_buf)?;
    let n = r.u64("node count")? as usize;
    let classes = r.u64("class count")? as usize;
    let indptr = r.u64s("indptr")?;
    let indices = r.u32s("indices")?;
    let rows = r.u64("feature rows")? as usize;
    let cols = r.u64("feature cols")? as usize;
    let data = r.f32s("features")?;
    anyhow::ensure!(
        rows.checked_mul(cols) == Some(data.len()),
        "corrupt dataset: feature shape {rows}x{cols} != {} values",
        data.len()
    );
    let labels = r.u32s("labels")?;
    let train = r.bools("train mask")?;
    let val = r.bools("val mask")?;
    let test = r.bools("test mask")?;
    let ds = Dataset {
        name: String::from_utf8(name_buf)?,
        graph: Csr { n, indptr, indices },
        features: Matrix::from_vec(rows, cols, data),
        labels,
        classes,
        split: Split { train, val, test },
    };
    ds.validate()?;
    Ok(ds)
}

// ---------------------------------------------------------------------------
// v2: sharded out-of-core format
// ---------------------------------------------------------------------------

pub const SHARD_SCHEMA: &str = "varco-shards/2";
pub const MANIFEST_FILE: &str = "manifest.json";

/// One file entry in the shard manifest.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardFile {
    /// filename relative to the shard directory
    pub path: String,
    pub bytes: u64,
    /// FNV-1a hash of the file's contents
    pub hash: u64,
}

/// Manifest describing a sharded dataset directory.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardManifest {
    pub name: String,
    pub n: usize,
    pub classes: usize,
    pub f_in: usize,
    pub num_edges: usize,
    pub rows_per_shard: usize,
    pub files: Vec<ShardFile>,
}

impl ShardManifest {
    /// Combined content hash: a pure function of shard *contents* (file
    /// names, sizes, hashes, and the graph's shape), independent of where
    /// the directory lives on disk.
    pub fn content_hash(&self) -> u64 {
        let mut h = Fnv::new();
        let head = format!(
            "{}|{}|{}|{}|{}|{}",
            self.name, self.n, self.classes, self.f_in, self.num_edges, self.rows_per_shard
        );
        h.update(head.as_bytes());
        for f in &self.files {
            h.update(format!("|{}|{}|{:016x}", f.path, f.bytes, f.hash).as_bytes());
        }
        h.finish()
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        let files: Vec<Json> = self
            .files
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("path", Json::str(&f.path)),
                    ("bytes", Json::num(f.bytes as f64)),
                    // u64 does not fit a JSON double; hashes travel as hex
                    ("hash", Json::str(&format!("{:016x}", f.hash))),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("schema", Json::str(SHARD_SCHEMA)),
            ("name", Json::str(&self.name)),
            ("n", Json::num(self.n as f64)),
            ("classes", Json::num(self.classes as f64)),
            ("f_in", Json::num(self.f_in as f64)),
            ("num_edges", Json::num(self.num_edges as f64)),
            ("rows_per_shard", Json::num(self.rows_per_shard as f64)),
            ("files", Json::Arr(files)),
        ]);
        std::fs::write(dir.join(MANIFEST_FILE), doc.to_string_pretty() + "\n")?;
        Ok(())
    }

    pub fn load(dir: &Path) -> Result<ShardManifest> {
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read shard manifest {path:?}: {e}"))?;
        let j = Json::parse(&text)?;
        let schema = j.get("schema").and_then(|v| v.as_str()).unwrap_or_default();
        anyhow::ensure!(
            schema == SHARD_SCHEMA,
            "unsupported shard manifest schema {schema:?} (want {SHARD_SCHEMA})"
        );
        let usize_field = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow::anyhow!("shard manifest missing field {k:?}"))
        };
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("shard manifest missing field \"name\""))?
            .to_string();
        let mut files = Vec::new();
        let entries = match j.get("files") {
            Some(Json::Arr(a)) => a,
            _ => anyhow::bail!("shard manifest missing file list"),
        };
        for e in entries {
            let path = e
                .get("path")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow::anyhow!("manifest file entry missing path"))?;
            anyhow::ensure!(
                !path.contains('/') && !path.contains("..") && !path.is_empty(),
                "manifest file entry {path:?} escapes the shard directory"
            );
            let bytes = e
                .get("bytes")
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow::anyhow!("manifest file entry missing bytes"))?
                as u64;
            let hash_hex = e
                .get("hash")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow::anyhow!("manifest file entry missing hash"))?;
            let hash = u64::from_str_radix(hash_hex, 16)
                .map_err(|_| anyhow::anyhow!("manifest hash {hash_hex:?} is not hex"))?;
            files.push(ShardFile { path: path.to_string(), bytes, hash });
        }
        let m = ShardManifest {
            name,
            n: usize_field("n")?,
            classes: usize_field("classes")?,
            f_in: usize_field("f_in")?,
            num_edges: usize_field("num_edges")?,
            rows_per_shard: usize_field("rows_per_shard")?,
            files,
        };
        anyhow::ensure!(m.rows_per_shard > 0, "shard manifest rows_per_shard must be > 0");
        anyhow::ensure!(m.f_in > 0, "shard manifest f_in must be > 0");
        Ok(m)
    }
}

/// Writer that hashes every byte it forwards.
struct HashingWriter<W> {
    w: W,
    h: Fnv,
    bytes: u64,
}

impl<W: Write> HashingWriter<W> {
    fn new(w: W) -> HashingWriter<W> {
        HashingWriter { w, h: Fnv::new(), bytes: 0 }
    }

    fn put(&mut self, bytes: &[u8]) -> Result<()> {
        self.w.write_all(bytes)?;
        self.h.update(bytes);
        self.bytes += bytes.len() as u64;
        Ok(())
    }

    fn done(mut self, path: &str) -> Result<ShardFile> {
        self.w.flush()?;
        Ok(ShardFile { path: path.to_string(), bytes: self.bytes, hash: self.h.finish() })
    }
}

fn shard_file(dir: &Path, name: &str) -> Result<HashingWriter<BufWriter<std::fs::File>>> {
    Ok(HashingWriter::new(BufWriter::new(std::fs::File::create(dir.join(name))?)))
}

/// Write `ds` as a v2 shard directory and return the manifest (also
/// saved as `manifest.json` in `dir`).
pub fn write_shards(ds: &Dataset, dir: &Path, rows_per_shard: usize) -> Result<ShardManifest> {
    anyhow::ensure!(rows_per_shard > 0, "rows_per_shard must be > 0");
    ds.validate()?;
    std::fs::create_dir_all(dir)?;
    let n = ds.graph.n;
    let mut files = Vec::new();

    let mut w = shard_file(dir, "indptr.bin")?;
    for &p in &ds.graph.indptr {
        w.put(&p.to_le_bytes())?;
    }
    files.push(w.done("indptr.bin")?);

    let mut w = shard_file(dir, "indices.bin")?;
    for &v in &ds.graph.indices {
        w.put(&v.to_le_bytes())?;
    }
    files.push(w.done("indices.bin")?);

    let mut w = shard_file(dir, "labels.bin")?;
    for &y in &ds.labels {
        w.put(&y.to_le_bytes())?;
    }
    files.push(w.done("labels.bin")?);

    let mut w = shard_file(dir, "split.bin")?;
    for i in 0..n {
        let b = ds.split.train[i] as u8 | (ds.split.val[i] as u8) << 1 | (ds.split.test[i] as u8) << 2;
        w.put(&[b])?;
    }
    files.push(w.done("split.bin")?);

    let shards = if n == 0 { 0 } else { (n + rows_per_shard - 1) / rows_per_shard };
    for s in 0..shards {
        let name = format!("features_{s:04}.bin");
        let mut w = shard_file(dir, &name)?;
        let lo = s * rows_per_shard;
        let hi = ((s + 1) * rows_per_shard).min(n);
        for r in lo..hi {
            for &x in ds.features.row(r) {
                w.put(&x.to_le_bytes())?;
            }
        }
        files.push(w.done(&name)?);
    }

    let manifest = ShardManifest {
        name: ds.name.clone(),
        n,
        classes: ds.classes,
        f_in: ds.f_in(),
        num_edges: ds.graph.num_edges(),
        rows_per_shard,
        files,
    };
    manifest.save(dir)?;
    Ok(manifest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::TempDir;

    #[test]
    fn round_trip_preserves_everything() {
        let ds = Dataset::load("karate-like", 0, 5).unwrap();
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("ds.bin");
        save_dataset(&ds, &path).unwrap();
        let back = load_dataset(&path).unwrap();
        assert_eq!(ds.name, back.name);
        assert_eq!(ds.graph, back.graph);
        assert_eq!(ds.features, back.features);
        assert_eq!(ds.labels, back.labels);
        assert_eq!(ds.split, back.split);
        assert_eq!(ds.classes, back.classes);
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("junk.bin");
        std::fs::write(&path, b"notadataset....").unwrap();
        let err = load_dataset(&path).unwrap_err();
        assert!(err.to_string().contains("bad magic"));
    }

    #[test]
    fn truncated_file_errors_cleanly() {
        let ds = Dataset::load("karate-like", 0, 5).unwrap();
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("ds.bin");
        save_dataset(&ds, &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load_dataset(&path).is_err());
    }

    #[test]
    fn huge_declared_length_rejected_before_allocating() {
        // corrupt the name-length header (bytes 8..16) to u64::MAX: the
        // loader must reject on the remaining-bytes budget, not attempt a
        // 2^64-byte allocation
        let ds = Dataset::load("karate-like", 0, 5).unwrap();
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("ds.bin");
        save_dataset(&ds, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load_dataset(&path).unwrap_err();
        assert!(err.to_string().contains("remain"), "{err}");
    }

    #[test]
    fn huge_section_count_overflow_rejected() {
        // a section count whose byte size overflows u64 must also fail
        // cleanly; indptr length sits right after magic+name+n+classes
        let ds = Dataset::load("karate-like", 0, 5).unwrap();
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("ds.bin");
        save_dataset(&ds, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let off = 8 + 8 + ds.name.len() + 8 + 8; // -> indptr length field
        bytes[off..off + 8].copy_from_slice(&u64::MAX.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load_dataset(&path).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("overflow") || msg.contains("remain"), "{msg}");
    }

    #[test]
    fn bit_flipped_adjacency_rejected_by_validation() {
        // flip a neighbor id in the indices section: the loaded graph is
        // no longer symmetric/in-range and validate() must catch it
        let ds = Dataset::load("karate-like", 0, 5).unwrap();
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("ds.bin");
        save_dataset(&ds, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let off = 8 + 8 + ds.name.len() + 8 + 8 + 8 + ds.graph.indptr.len() * 8 + 8;
        bytes[off] ^= 0xFF; // karate-like has n=64, so v ^ 0xFF >= 191 is out of range
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_dataset(&path).is_err());
    }

    #[test]
    fn shard_write_matches_manifest() {
        let ds = Dataset::load("karate-like", 0, 3).unwrap();
        let dir = TempDir::new().unwrap();
        let m = write_shards(&ds, dir.path(), 16).unwrap();
        assert_eq!(m.n, ds.n());
        assert_eq!(m.f_in, ds.f_in());
        assert_eq!(m.num_edges, ds.graph.num_edges());
        assert_eq!(m.files.iter().filter(|f| f.path.starts_with("features_")).count(), 4);
        for f in &m.files {
            let got = std::fs::metadata(dir.path().join(&f.path)).unwrap().len();
            assert_eq!(got, f.bytes, "{}", f.path);
        }
        // manifest round-trips exactly, including the content hash
        let back = ShardManifest::load(dir.path()).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.content_hash(), m.content_hash());
    }

    #[test]
    fn shard_content_hash_tracks_contents_not_location() {
        let ds = Dataset::load("karate-like", 0, 3).unwrap();
        let a = TempDir::new().unwrap();
        let b = TempDir::new().unwrap();
        let ma = write_shards(&ds, a.path(), 16).unwrap();
        let mb = write_shards(&ds, b.path(), 16).unwrap();
        assert_eq!(ma.content_hash(), mb.content_hash(), "same bytes, different dirs");
        let other = Dataset::load("karate-like", 0, 4).unwrap();
        let c = TempDir::new().unwrap();
        let mc = write_shards(&other, c.path(), 16).unwrap();
        assert_ne!(ma.content_hash(), mc.content_hash(), "different features must differ");
        let md = write_shards(&ds, c.path(), 8).unwrap();
        assert_ne!(ma.content_hash(), md.content_hash(), "different sharding must differ");
    }
}
