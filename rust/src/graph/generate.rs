//! Synthetic graph generators: Erdős–Rényi, Barabási–Albert, stochastic
//! block model, R-MAT.  All deterministic given a seed.

use super::Csr;
use crate::util::Rng;

/// G(n, p) Erdős–Rényi via geometric edge skipping (O(m)).
pub fn erdos_renyi(n: usize, p: f64, seed: u64) -> Csr {
    assert!((0.0..=1.0).contains(&p));
    let mut rng = Rng::new(seed);
    let mut edges = Vec::new();
    if p > 0.0 && n > 1 {
        let lq = (1.0 - p).ln();
        let total = n * (n - 1) / 2;
        let mut k: i64 = -1;
        loop {
            let r = rng.next_f64().max(1e-300);
            let skip = if p >= 1.0 { 1 } else { 1 + (r.ln() / lq).floor() as i64 };
            k += skip.max(1);
            if k as usize >= total {
                break;
            }
            let (u, v) = pair_from_index(k as usize);
            edges.push((u as u32, v as u32));
        }
    }
    Csr::from_edges(n, &edges)
}

/// Map linear index k in [0, n(n-1)/2) to the k-th (u < v) pair.
fn pair_from_index(k: usize) -> (usize, usize) {
    // Solve v(v-1)/2 <= k: v = floor((1 + sqrt(1+8k)) / 2)
    let v = ((1.0 + (1.0 + 8.0 * k as f64).sqrt()) / 2.0).floor() as usize;
    let v = if v * (v - 1) / 2 > k { v - 1 } else { v };
    let u = k - v * (v - 1) / 2;
    (u, v)
}

/// Barabási–Albert preferential attachment with `m` edges per new node.
pub fn barabasi_albert(n: usize, m: usize, seed: u64) -> Csr {
    assert!(m >= 1 && n > m, "need n > m >= 1");
    let mut rng = Rng::new(seed);
    let mut targets: Vec<u32> = Vec::new(); // repeated-node list ∝ degree
    let mut edges = Vec::new();
    // Seed clique over the first m+1 nodes.
    for u in 0..=m {
        for v in (u + 1)..=m {
            edges.push((u as u32, v as u32));
            targets.push(u as u32);
            targets.push(v as u32);
        }
    }
    for u in (m + 1)..n {
        let mut picked = std::collections::HashSet::new();
        while picked.len() < m {
            let t = targets[rng.next_below(targets.len())];
            picked.insert(t);
        }
        for &t in &picked {
            edges.push((u as u32, t));
            targets.push(u as u32);
            targets.push(t);
        }
    }
    Csr::from_edges(n, &edges)
}

/// Stochastic block model: `blocks` communities of equal size, intra-block
/// probability `p_in`, inter-block `p_out`.  Returns (graph, block id per
/// node).  Block assignment is contiguous then shuffled so node ids carry
/// no community information (matters for random partitioning realism).
pub fn sbm(n: usize, blocks: usize, p_in: f64, p_out: f64, seed: u64) -> (Csr, Vec<u32>) {
    assert!(blocks >= 1 && n >= blocks);
    let mut rng = Rng::new(seed);
    let mut assignment: Vec<u32> = (0..n).map(|i| (i % blocks) as u32).collect();
    rng.shuffle(&mut assignment);
    let mut edges = Vec::new();
    // Group nodes by block for O(within) + bernoulli sampling across pairs
    // of blocks via ER-style skipping on the pair index.
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); blocks];
    for (i, &b) in assignment.iter().enumerate() {
        members[b as usize].push(i as u32);
    }
    for a in 0..blocks {
        for b in a..blocks {
            let p = if a == b { p_in } else { p_out };
            if p <= 0.0 {
                continue;
            }
            sample_block_pair(&members[a], &members[b], a == b, p, &mut rng, &mut edges);
        }
    }
    (Csr::from_edges(n, &edges), assignment)
}

fn sample_block_pair(
    xs: &[u32],
    ys: &[u32],
    same: bool,
    p: f64,
    rng: &mut Rng,
    edges: &mut Vec<(u32, u32)>,
) {
    let total = if same { xs.len() * (xs.len().saturating_sub(1)) / 2 } else { xs.len() * ys.len() };
    if total == 0 {
        return;
    }
    let lq = (1.0 - p).ln();
    let mut k: i64 = -1;
    loop {
        let r = rng.next_f64().max(1e-300);
        let skip = if p >= 1.0 { 1 } else { 1 + (r.ln() / lq).floor() as i64 };
        k += skip.max(1);
        if k as usize >= total {
            break;
        }
        let (i, j) = if same {
            let (u, v) = super::generate::pair_from_index(k as usize);
            (xs[u], xs[v])
        } else {
            let idx = k as usize;
            (xs[idx / ys.len()], ys[idx % ys.len()])
        };
        edges.push((i, j));
    }
}

/// R-MAT power-law generator (Chakrabarti et al.): 2^scale nodes,
/// `edge_factor * n` directed samples symmetrized.
pub fn rmat(scale: u32, edge_factor: usize, seed: u64) -> Csr {
    let n = 1usize << scale;
    let (a, b, c) = (0.57, 0.19, 0.19); // Graph500 parameters
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(edge_factor * n);
    for _ in 0..edge_factor * n {
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            u <<= 1;
            v <<= 1;
            let r = rng.next_f64();
            if r < a {
            } else if r < a + b {
                v |= 1;
            } else if r < a + b + c {
                u |= 1;
            } else {
                u |= 1;
                v |= 1;
            }
        }
        if u != v {
            edges.push((u as u32, v as u32));
        }
    }
    Csr::from_edges(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn er_density_close_to_p() {
        let n = 500;
        let p = 0.05;
        let g = erdos_renyi(n, p, 1);
        let expected = p * (n * (n - 1) / 2) as f64;
        let got = g.num_edges() as f64;
        assert!((got - expected).abs() < 0.15 * expected, "{got} vs {expected}");
        g.validate().unwrap();
    }

    #[test]
    fn er_deterministic() {
        assert_eq!(erdos_renyi(100, 0.1, 7), erdos_renyi(100, 0.1, 7));
        assert_ne!(erdos_renyi(100, 0.1, 7), erdos_renyi(100, 0.1, 8));
    }

    #[test]
    fn er_p_zero_and_edge_cases() {
        assert_eq!(erdos_renyi(10, 0.0, 1).num_edges(), 0);
        assert_eq!(erdos_renyi(1, 0.5, 1).num_edges(), 0);
        assert_eq!(erdos_renyi(0, 0.5, 1).n, 0);
    }

    #[test]
    fn pair_index_bijective_prefix() {
        let mut seen = std::collections::HashSet::new();
        for k in 0..45 {
            let (u, v) = pair_from_index(k);
            assert!(u < v && v < 10, "k={k} -> ({u},{v})");
            assert!(seen.insert((u, v)));
        }
    }

    #[test]
    fn ba_has_expected_edge_count_and_hubs() {
        let g = barabasi_albert(300, 3, 2);
        // clique(4)=6 edges + 3 per node for 296 nodes
        assert_eq!(g.num_edges(), 6 + 3 * 296);
        let mut degs = g.degrees();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        assert!(degs[0] as f64 > 3.0 * g.avg_degree(), "hub degree {}", degs[0]);
        g.validate().unwrap();
    }

    #[test]
    fn sbm_intra_vs_inter_density() {
        let (g, blocks) = sbm(600, 3, 0.05, 0.005, 3);
        g.validate().unwrap();
        let mut intra = 0usize;
        let mut inter = 0usize;
        for u in 0..g.n {
            for &v in g.neighbors(u) {
                if u < v as usize {
                    if blocks[u] == blocks[v as usize] {
                        intra += 1;
                    } else {
                        inter += 1;
                    }
                }
            }
        }
        // intra pairs ≈ 3 * C(200,2) * 0.05 ≈ 2985; inter ≈ 3*200*200*0.005 = 600
        assert!(intra > 3 * inter, "intra={intra} inter={inter}");
    }

    #[test]
    fn sbm_balanced_blocks() {
        let (_, blocks) = sbm(100, 4, 0.1, 0.01, 5);
        let mut counts = [0usize; 4];
        for &b in &blocks {
            counts[b as usize] += 1;
        }
        assert_eq!(counts, [25, 25, 25, 25]);
    }

    #[test]
    fn rmat_is_skewed_and_valid() {
        let g = rmat(9, 8, 11);
        g.validate().unwrap();
        let mut degs = g.degrees();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        assert!((degs[0] as f64) > 4.0 * g.avg_degree());
    }
}
