//! Deterministic mini-batch + fanout neighbor sampling (GraphSAGE-style).
//!
//! `mode = sampled` draws one seeded batch of training nodes per epoch and
//! expands it layer by layer with per-layer fanout caps (CAGNET's sampled
//! SAGE branch mirrors the same `batch_size`/fanout knobs).  Every draw is
//! a pure function of `(seed, epoch)` — the batch — or
//! `(seed, epoch, layer, node)` — that node's neighbor subset — so the
//! parallel, sequential, and multi-process runtimes sample identically
//! without sharing any RNG state.
//!
//! The sampled node set induces a subgraph (all edges among sampled
//! nodes), which flows through the unchanged partition/WorkerGraph/
//! SendPlan machinery: sampled halo exchanges ride the same wire codec,
//! ledgers, and rate controllers as full-graph training.

use crate::graph::store::Adjacency;
use crate::graph::Csr;
use crate::util::Rng;
use crate::Result;

/// Per-layer neighbor cap: a positive count, or every neighbor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fanout {
    /// keep the full neighborhood at this layer ("inf"/"all" in config)
    All,
    /// sample at most this many neighbors per frontier node
    Limit(usize),
}

impl Fanout {
    /// Parse a comma-separated fanout list: `"10,10,5"` or `"inf,25"`.
    /// Entries must be positive integers or `inf`/`all`; the count is
    /// checked against `layers` by the caller (it owns that context).
    pub fn parse_list(s: &str) -> Result<Vec<Fanout>> {
        let t = s.trim();
        anyhow::ensure!(
            !t.is_empty(),
            "fanout must list one entry per layer, e.g. fanout=10,10,5 (or inf)"
        );
        t.split(',')
            .map(|tok| {
                let tok = tok.trim();
                match tok {
                    "inf" | "all" => Ok(Fanout::All),
                    _ => {
                        let v: usize = tok.parse().map_err(|_| {
                            anyhow::anyhow!(
                                "bad fanout entry {tok:?}: want a positive integer or inf"
                            )
                        })?;
                        anyhow::ensure!(v >= 1, "fanout entries must be >= 1, got {tok:?}");
                        Ok(Fanout::Limit(v))
                    }
                }
            })
            .collect()
    }

    pub fn label(&self) -> String {
        match self {
            Fanout::All => "inf".into(),
            Fanout::Limit(k) => k.to_string(),
        }
    }
}

/// Everything the sampler needs per run; epoch is passed per draw.
#[derive(Clone, Debug, PartialEq)]
pub struct SamplingConfig {
    pub batch_size: usize,
    /// one entry per GNN layer, outermost (input-side) hop last
    pub fanouts: Vec<Fanout>,
}

const BATCH_TAG: u64 = 0xBA7C_4A11;
const FANOUT_TAG: u64 = 0xFA40_0075;

/// Draw this epoch's batch of training nodes: `min(batch_size, |train|)`
/// ids, sorted ascending, a pure function of `(seed, epoch)`.
pub fn draw_batch(train_mask: &[bool], batch_size: usize, seed: u64, epoch: usize) -> Vec<u32> {
    let train_ids: Vec<u32> = train_mask
        .iter()
        .enumerate()
        .filter_map(|(i, &t)| t.then_some(i as u32))
        .collect();
    let m = batch_size.min(train_ids.len());
    let mut picks = Vec::with_capacity(m);
    Rng::new(seed)
        .derive(BATCH_TAG)
        .derive(epoch as u64)
        .sample_indices_into(train_ids.len(), m, &mut picks);
    let mut batch: Vec<u32> = picks.iter().map(|&i| train_ids[i as usize]).collect();
    batch.sort_unstable();
    batch
}

/// Expand the batch through `fanouts.len()` hops of neighbor sampling and
/// return the full sampled node set, sorted ascending.  Each frontier
/// node's neighbor subset is a pure function of
/// `(seed, epoch, layer, node)`, so the expansion order never matters.
pub fn sample_nodes(
    g: &dyn Adjacency,
    batch: &[u32],
    fanouts: &[Fanout],
    seed: u64,
    epoch: usize,
) -> Vec<u32> {
    let mut visited = vec![false; g.n_nodes()];
    let mut frontier: Vec<u32> = batch.to_vec();
    for &u in &frontier {
        visited[u as usize] = true;
    }
    let mut picks = Vec::new();
    let mut nbrs = Vec::new();
    for (layer, fanout) in fanouts.iter().enumerate() {
        let mut next = Vec::new();
        for &u in &frontier {
            g.neighbors_into(u as usize, &mut nbrs);
            let mut admit = |v: u32| {
                if !visited[v as usize] {
                    visited[v as usize] = true;
                    next.push(v);
                }
            };
            match *fanout {
                Fanout::Limit(k) if k < nbrs.len() => {
                    Rng::new(seed)
                        .derive(FANOUT_TAG)
                        .derive(epoch as u64)
                        .derive(layer as u64)
                        .derive(u as u64)
                        .sample_indices_into(nbrs.len(), k, &mut picks);
                    picks.sort_unstable();
                    for &i in &picks {
                        admit(nbrs[i as usize]);
                    }
                }
                _ => {
                    for &v in &nbrs {
                        admit(v);
                    }
                }
            }
        }
        next.sort_unstable();
        frontier = next;
    }
    let mut nodes: Vec<u32> =
        visited.iter().enumerate().filter_map(|(i, &v)| v.then_some(i as u32)).collect();
    nodes.sort_unstable();
    nodes
}

/// Induced subgraph on `nodes` (sorted ascending global ids): local id =
/// position in `nodes`, edges = every full-graph edge with both endpoints
/// sampled.  Keeping all intra-sample edges (rather than only sampled
/// tree edges) preserves symmetry, which the GCN normalization and the
/// boundary plans both assume.
pub fn induce(g: &dyn Adjacency, nodes: &[u32]) -> Csr {
    let local = |gid: u32| nodes.binary_search(&gid).ok();
    let mut edges = Vec::new();
    let mut nbrs = Vec::new();
    for (lu, &u) in nodes.iter().enumerate() {
        g.neighbors_into(u as usize, &mut nbrs);
        for &v in &nbrs {
            if u < v {
                if let Some(lv) = local(v) {
                    edges.push((lu as u32, lv as u32));
                }
            }
        }
    }
    Csr::from_edges(nodes.len(), &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path_graph(n: usize) -> Csr {
        let edges: Vec<(u32, u32)> = (0..n as u32 - 1).map(|i| (i, i + 1)).collect();
        Csr::from_edges(n, &edges)
    }

    #[test]
    fn fanout_parsing() {
        assert_eq!(
            Fanout::parse_list("10, 5,inf").unwrap(),
            vec![Fanout::Limit(10), Fanout::Limit(5), Fanout::All]
        );
        assert_eq!(Fanout::parse_list("all").unwrap(), vec![Fanout::All]);
        assert!(Fanout::parse_list("").is_err());
        assert!(Fanout::parse_list("10,zero").is_err());
        assert!(Fanout::parse_list("10,0").is_err());
        assert!(Fanout::parse_list("10,-3").is_err());
        assert_eq!(Fanout::Limit(7).label(), "7");
        assert_eq!(Fanout::All.label(), "inf");
    }

    #[test]
    fn batch_draws_are_deterministic_and_within_mask() {
        let mask: Vec<bool> = (0..64).map(|i| i % 2 == 0).collect();
        let a = draw_batch(&mask, 8, 3, 5);
        let b = draw_batch(&mask, 8, 3, 5);
        assert_eq!(a, b, "same (seed, epoch) must draw the same batch");
        assert_eq!(a.len(), 8);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "sorted, unique");
        assert!(a.iter().all(|&u| mask[u as usize]), "batch must be training nodes");
        // different epochs draw different batches (with overwhelming odds)
        assert_ne!(a, draw_batch(&mask, 8, 3, 6));
        // oversized requests clamp to the full training set
        assert_eq!(draw_batch(&mask, 999, 3, 0).len(), 32);
    }

    #[test]
    fn infinite_fanout_reaches_the_full_k_hop_neighborhood() {
        let g = path_graph(10);
        let nodes = sample_nodes(&g, &[4], &[Fanout::All, Fanout::All], 0, 0);
        // 2 hops from node 4 on a path: 2..=6
        assert_eq!(nodes, vec![2, 3, 4, 5, 6]);
    }

    #[test]
    fn limited_fanout_bounds_the_expansion() {
        let g = path_graph(101);
        for epoch in 0..4 {
            let nodes = sample_nodes(&g, &[50], &[Fanout::Limit(1), Fanout::Limit(1)], 9, epoch);
            // each hop admits at most one new node per frontier node
            assert!(nodes.len() <= 1 + 1 + 1, "{nodes:?}");
            assert!(nodes.contains(&50));
            assert_eq!(
                nodes,
                sample_nodes(&g, &[50], &[Fanout::Limit(1), Fanout::Limit(1)], 9, epoch),
                "per-node draws must be deterministic"
            );
        }
    }

    #[test]
    fn induced_subgraph_keeps_exactly_the_intra_sample_edges() {
        let g = path_graph(6);
        let nodes = vec![1u32, 2, 4, 5];
        let sub = induce(&g, &nodes);
        assert_eq!(sub.n, 4);
        // local 0=gid1, 1=gid2, 2=gid4, 3=gid5: edges (1,2) and (4,5) survive
        assert!(sub.has_edge(0, 1));
        assert!(sub.has_edge(2, 3));
        assert!(!sub.has_edge(1, 2), "gid 2-4 are not adjacent in the path");
        sub.validate().unwrap();
    }
}
