//! Feature / label synthesis for the synthetic datasets.
//!
//! Labels follow a finer-grained community structure than the SBM blocks
//! (several classes per block), and features are noisy class prototypes.
//! The signal-to-noise ratio is tuned so that (a) a featureless classifier
//! fails, (b) a no-aggregation MLP is mediocre, and (c) neighborhood
//! aggregation recovers most of the signal — the regime where the paper's
//! communication/accuracy trade-off is visible (NoComm clearly below
//! FullComm, Table II).

use crate::tensor::Matrix;
use crate::util::Rng;

/// Synthesize `classes` prototypes in `dim` dimensions and emit one noisy
/// sample per node.  `noise` is the per-coordinate Gaussian noise std
/// relative to unit-norm prototypes.
pub struct FeatureSynth {
    pub dim: usize,
    pub classes: usize,
    pub noise: f32,
    /// Fraction of a node's feature replaced by a *random other* class
    /// prototype (label noise in feature space) — keeps local-only
    /// classification imperfect so communication matters.
    pub confusion: f32,
}

impl FeatureSynth {
    /// Assign labels: nodes in SBM block b draw from classes congruent to
    /// b modulo `classes` with locality bias, so classes correlate with
    /// graph structure (like citation areas within arXiv sub-fields).
    pub fn labels_from_blocks(&self, blocks: &[u32], n_blocks: usize, rng: &mut Rng) -> Vec<u32> {
        let per_block = (self.classes as f64 / n_blocks as f64).ceil() as usize;
        blocks
            .iter()
            .map(|&b| {
                let base = (b as usize * per_block) % self.classes;
                let off = rng.next_below(per_block.max(1));
                ((base + off) % self.classes) as u32
            })
            .collect()
    }

    /// Noisy prototype features, then one round of neighbor mixing applied
    /// by the caller if desired.
    pub fn features(&self, labels: &[u32], rng: &mut Rng) -> Matrix {
        let protos = self.prototypes(rng);
        let n = labels.len();
        let mut x = Matrix::zeros(n, self.dim);
        for i in 0..n {
            let y = labels[i] as usize;
            let src = if rng.next_f32() < self.confusion {
                rng.next_below(self.classes)
            } else {
                y
            };
            let row = x.row_mut(i);
            for (j, r) in row.iter_mut().enumerate() {
                *r = protos.get(src, j) + self.noise * rng.next_normal();
            }
        }
        x
    }

    /// Unit-norm random class prototypes.
    pub fn prototypes(&self, rng: &mut Rng) -> Matrix {
        let mut p = Matrix::from_fn(self.classes, self.dim, |_, _| rng.next_normal());
        for i in 0..self.classes {
            let row = p.row_mut(i);
            let norm = row.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-6);
            for v in row.iter_mut() {
                *v /= norm;
            }
        }
        p
    }
}

/// Train/val/test split masks (fractions of nodes, disjoint, seeded).
pub fn random_split(n: usize, train: f64, val: f64, rng: &mut Rng) -> (Vec<bool>, Vec<bool>, Vec<bool>) {
    assert!(train + val <= 1.0);
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let n_train = (n as f64 * train).round() as usize;
    let n_val = (n as f64 * val).round() as usize;
    let mut m_train = vec![false; n];
    let mut m_val = vec![false; n];
    let mut m_test = vec![false; n];
    for (rank, &i) in order.iter().enumerate() {
        if rank < n_train {
            m_train[i] = true;
        } else if rank < n_train + n_val {
            m_val[i] = true;
        } else {
            m_test[i] = true;
        }
    }
    (m_train, m_val, m_test)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth() -> FeatureSynth {
        FeatureSynth { dim: 16, classes: 6, noise: 0.3, confusion: 0.1 }
    }

    #[test]
    fn labels_cover_classes_and_respect_blocks() {
        let mut rng = Rng::new(1);
        let blocks: Vec<u32> = (0..600).map(|i| (i % 3) as u32).collect();
        let labels = synth().labels_from_blocks(&blocks, 3, &mut rng);
        assert!(labels.iter().all(|&y| y < 6));
        // block 0 nodes only get classes {0,1}, block 1 -> {2,3}, etc.
        for (i, &y) in labels.iter().enumerate() {
            let b = blocks[i] as usize;
            assert!(y as usize / 2 == b, "block {b} got class {y}");
        }
    }

    #[test]
    fn features_correlate_with_class_prototypes() {
        let mut rng = Rng::new(2);
        let s = synth();
        let labels: Vec<u32> = (0..300).map(|i| (i % 6) as u32).collect();
        let mut rng2 = rng.clone();
        let protos = s.prototypes(&mut rng2);
        let x = s.features(&labels, &mut rng);
        // mean cosine similarity with own prototype far above cross-class
        let mut own = 0.0f32;
        let mut cross = 0.0f32;
        for i in 0..300 {
            let xi = x.row(i);
            let cos = |p: &[f32]| {
                let dot: f32 = xi.iter().zip(p).map(|(a, b)| a * b).sum();
                let nx = xi.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-6);
                dot / nx
            };
            own += cos(protos.row(labels[i] as usize));
            cross += cos(protos.row(((labels[i] + 3) % 6) as usize));
        }
        assert!(own > cross + 50.0, "own={own} cross={cross}");
    }

    #[test]
    fn split_is_disjoint_and_complete() {
        let mut rng = Rng::new(3);
        let (tr, va, te) = random_split(100, 0.6, 0.2, &mut rng);
        let mut n_tr = 0;
        for i in 0..100 {
            let cnt = tr[i] as u8 + va[i] as u8 + te[i] as u8;
            assert_eq!(cnt, 1, "node {i} in {cnt} splits");
            n_tr += tr[i] as usize;
        }
        assert_eq!(n_tr, 60);
        assert_eq!(va.iter().filter(|&&b| b).count(), 20);
    }

    #[test]
    fn split_deterministic_per_seed() {
        let (a, _, _) = random_split(50, 0.5, 0.25, &mut Rng::new(4));
        let (b, _, _) = random_split(50, 0.5, 0.25, &mut Rng::new(4));
        assert_eq!(a, b);
    }
}
