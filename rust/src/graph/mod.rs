//! Graph substrate: CSR storage, synthetic generators, datasets, IO.
//!
//! The paper trains on OGBN-Arxiv / OGBN-Products; those datasets are not
//! available here, so `datasets` provides SBM-based synthetic equivalents
//! (`synth-arxiv`, `synth-products`) that preserve what VARCO's claims
//! depend on: community structure (partition cross-edge profiles, Table I)
//! and feature–label correlation recoverable through aggregation
//! (DESIGN.md §2).

pub mod csr;
pub mod datasets;
pub mod features;
pub mod generate;
pub mod io;
pub mod sample;
pub mod store;

pub use csr::Csr;
pub use datasets::{Dataset, Split};
pub use sample::{Fanout, SamplingConfig};
pub use store::{Adjacency, GraphStore, MmapStore, ResidentStore, ShardSummary};
