//! Dataset registry: graph + features + labels + split masks.
//!
//! `synth-arxiv` / `synth-products` are the OGBN substitutions documented
//! in DESIGN.md §2: SBM community graphs with class-prototype features at
//! the paper's feature/class dimensions, sized to run the full experiment
//! grid on one machine (scalable via `--nodes`).

use super::features::{random_split, FeatureSynth};
use super::generate;
use super::Csr;
use crate::tensor::Matrix;
use crate::util::Rng;
use crate::{Result};

/// Train/val/test node masks.
#[derive(Clone, Debug, PartialEq)]
pub struct Split {
    pub train: Vec<bool>,
    pub val: Vec<bool>,
    pub test: Vec<bool>,
}

impl Split {
    pub fn as_f32(&self) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let f = |v: &Vec<bool>| v.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect();
        (f(&self.train), f(&self.val), f(&self.test))
    }
}

/// A node-classification dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub graph: Csr,
    pub features: Matrix, // n x f_in
    pub labels: Vec<u32>, // n, values < classes
    pub classes: usize,
    pub split: Split,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.graph.n
    }

    pub fn f_in(&self) -> usize {
        self.features.cols
    }

    pub fn validate(&self) -> Result<()> {
        self.graph.validate()?;
        anyhow::ensure!(self.features.rows == self.graph.n, "feature rows != n");
        anyhow::ensure!(self.labels.len() == self.graph.n, "labels != n");
        anyhow::ensure!(
            self.labels.iter().all(|&y| (y as usize) < self.classes),
            "label out of range"
        );
        for i in 0..self.graph.n {
            let c = self.split.train[i] as u8 + self.split.val[i] as u8 + self.split.test[i] as u8;
            anyhow::ensure!(c == 1, "node {i} in {c} splits");
        }
        Ok(())
    }

    /// Build a registered dataset.  `nodes == 0` uses the default size.
    pub fn load(name: &str, nodes: usize, seed: u64) -> Result<Dataset> {
        match name {
            // blocks == classes: like citation/co-purchase graphs, edges
            // are class-assortative, so neighborhood aggregation carries
            // the label signal — the regime where the communication /
            // accuracy trade-off of the paper is visible.
            "synth-arxiv" => Ok(synth_citation(
                "synth-arxiv",
                if nodes == 0 { 8192 } else { nodes },
                128,
                40,
                40,
                6.0,  // avg intra-degree contribution
                1.5,  // avg inter-degree contribution
                seed,
            )),
            "synth-products" => Ok(synth_citation(
                "synth-products",
                if nodes == 0 { 16384 } else { nodes },
                100,
                47,
                47,
                18.0, // products is much denser (25x edges/node vs arxiv)
                4.0,
                seed,
            )),
            "karate-like" => Ok(tiny_demo(seed)),
            _ => anyhow::bail!("unknown dataset {name}; known: synth-arxiv, synth-products, karate-like"),
        }
    }
}

/// SBM + prototype features, OGBN-like knobs.
#[allow(clippy::too_many_arguments)]
fn synth_citation(
    name: &str,
    n: usize,
    dim: usize,
    classes: usize,
    blocks: usize,
    deg_in: f64,
    deg_out: f64,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::new(seed);
    let nb = n as f64 / blocks as f64;
    // degrees -> probabilities: deg_in ≈ p_in * nb, deg_out ≈ p_out * (n - nb)
    let p_in = (deg_in / nb).min(1.0);
    let p_out = (deg_out / (n as f64 - nb)).min(1.0);
    let (graph, block_ids) = generate::sbm(n, blocks, p_in, p_out, rng.next_u64());
    // Feature noise calibrated so a feature-only model (≈ NoComm under
    // random partitioning at large q) reaches ~60% of full-comm accuracy,
    // mirroring OGBN-arxiv's NoComm/FullComm ratio (~0.79 in Table II):
    // individual features are useful but neighborhood aggregation is
    // clearly better — the regime the paper's byte-efficiency claim
    // (Fig. 5) lives in.  Override with VARCO_NOISE for sensitivity runs.
    let noise = std::env::var("VARCO_NOISE").ok().and_then(|v| v.parse().ok()).unwrap_or(0.35);
    let synth = FeatureSynth { dim, classes, noise, confusion: 0.05 };
    let labels = synth.labels_from_blocks(&block_ids, blocks, &mut rng);
    let features = synth.features(&labels, &mut rng);
    let (train, val, test) = random_split(n, 0.55, 0.18, &mut rng);
    Dataset {
        name: name.to_string(),
        graph,
        features,
        labels,
        classes,
        split: Split { train, val, test },
    }
}

/// 64-node demo dataset for docs/tests.
fn tiny_demo(seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let (graph, blocks) = generate::sbm(64, 2, 0.3, 0.02, rng.next_u64());
    let synth = FeatureSynth { dim: 8, classes: 2, noise: 0.5, confusion: 0.05 };
    let labels = synth.labels_from_blocks(&blocks, 2, &mut rng);
    let features = synth.features(&labels, &mut rng);
    let (train, val, test) = random_split(64, 0.5, 0.2, &mut rng);
    Dataset {
        name: "karate-like".into(),
        graph,
        features,
        labels,
        classes: 2,
        split: Split { train, val, test },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_arxiv_shapes_and_validity() {
        let d = Dataset::load("synth-arxiv", 1024, 7).unwrap();
        d.validate().unwrap();
        assert_eq!(d.n(), 1024);
        assert_eq!(d.f_in(), 128);
        assert_eq!(d.classes, 40);
        assert!(d.graph.avg_degree() > 4.0, "avg deg {}", d.graph.avg_degree());
    }

    #[test]
    fn synth_products_is_denser() {
        let a = Dataset::load("synth-arxiv", 2048, 7).unwrap();
        let p = Dataset::load("synth-products", 2048, 7).unwrap();
        assert!(p.graph.avg_degree() > 2.0 * a.graph.avg_degree());
        assert_eq!(p.f_in(), 100);
        assert_eq!(p.classes, 47);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Dataset::load("synth-arxiv", 512, 3).unwrap();
        let b = Dataset::load("synth-arxiv", 512, 3).unwrap();
        assert_eq!(a.graph, b.graph);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.features.data, b.features.data);
    }

    #[test]
    fn unknown_dataset_errors() {
        assert!(Dataset::load("ogbn-arxiv", 0, 0).is_err());
    }

    #[test]
    fn tiny_demo_valid() {
        let d = Dataset::load("karate-like", 0, 1).unwrap();
        d.validate().unwrap();
        assert_eq!(d.n(), 64);
    }

    #[test]
    fn default_sizes() {
        // don't build the full default (slow in debug); just check knobs
        let d = Dataset::load("synth-arxiv", 256, 0).unwrap();
        assert_eq!(d.split.train.iter().filter(|&&b| b).count(), 141); // 55%
    }
}
