//! Graph storage abstraction: adjacency + feature access behind a trait.
//!
//! Everything downstream of the dataset — partitioners, worker-graph
//! construction, fanout sampling, mini-batch views, evaluation — used to
//! take `&Csr` / `&Dataset` and therefore assumed the whole graph was
//! resident in RAM.  [`Adjacency`] and [`GraphStore`] split that contract
//! into the two things consumers actually need (neighbor lists and row
//! gathers), so the same training stack runs against:
//!
//!  * [`ResidentStore`] — wraps today's in-memory [`Dataset`]; the bitwise
//!    oracle and the default (`store = resident`);
//!  * [`MmapStore`] — opens the sharded on-disk format written by
//!    `varco dataset build --format shard` (see [`crate::graph::io`]).
//!    CSR `indptr`/`indices` segments are memory-mapped; feature rows are
//!    gathered with positioned reads (pread) so untouched rows never enter
//!    the process's resident set, and labels/split masks (4+1 bytes per
//!    node) are loaded eagerly.
//!
//! Bitwise contract: both backends must expose identical neighbor
//! iteration order and identical f32 row bytes, so every consumer is
//! backend-oblivious and the existing equivalence suites pin
//! `store=mmap == store=resident` end to end.

use std::fs::File;
use std::path::{Path, PathBuf};

use super::io::{Fnv, ShardManifest};
use super::{Csr, Dataset, Split};
use crate::tensor::Matrix;
use crate::Result;

/// Neighbor access for one undirected graph.  `neighbors_into` clears the
/// buffer and fills it with the node's sorted neighbor list — the same
/// order `Csr::neighbors` exposes, which every deterministic accumulation
/// in the trainer depends on.
pub trait Adjacency: Send + Sync {
    fn n_nodes(&self) -> usize;
    /// Undirected edge count (half the total adjacency length).
    fn num_edges(&self) -> usize;
    fn degree(&self, v: usize) -> usize;
    fn neighbors_into(&self, v: usize, buf: &mut Vec<u32>);
}

/// Shard/backend telemetry surfaced through `varco describe` and the
/// RunReport.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSummary {
    /// number of fixed-stride feature shard files
    pub shards: usize,
    /// bytes of memory-mapped adjacency segments (indptr + indices)
    pub mapped_bytes: usize,
    /// manifest content hash (joins the dist admission hash)
    pub content_hash: u64,
}

/// A full node-classification graph store: adjacency plus features,
/// labels, and split masks.
pub trait GraphStore: Adjacency {
    fn name(&self) -> &str;
    fn classes(&self) -> usize;
    fn f_in(&self) -> usize;
    fn split(&self) -> &Split;
    /// Gather feature rows for global node ids `rows` into `out`
    /// (reshaped to `rows.len() x f_in`).  Row `i` of `out` is the
    /// feature vector of node `rows[i]`, byte-identical across backends.
    fn gather_rows(&self, rows: &[u32], out: &mut Matrix) -> Result<()>;
    /// Gather labels for `rows` (clears `out`).
    fn gather_labels(&self, rows: &[u32], out: &mut Vec<u32>) -> Result<()>;
    /// Backend tag: `"resident"` or `"mmap"`.
    fn backend(&self) -> &'static str;
    /// Shard telemetry; `None` for fully-resident backends.
    fn shard_summary(&self) -> Option<ShardSummary> {
        None
    }
    /// Manual supertrait upcast (`&dyn GraphStore -> &dyn Adjacency`
    /// without relying on trait-object upcasting support).
    fn adj(&self) -> &dyn Adjacency;
}

impl Adjacency for Csr {
    fn n_nodes(&self) -> usize {
        self.n
    }

    fn num_edges(&self) -> usize {
        self.indices.len() / 2
    }

    fn degree(&self, v: usize) -> usize {
        (self.indptr[v + 1] - self.indptr[v]) as usize
    }

    fn neighbors_into(&self, v: usize, buf: &mut Vec<u32>) {
        buf.clear();
        buf.extend_from_slice(self.neighbors(v));
    }
}

impl Adjacency for Dataset {
    fn n_nodes(&self) -> usize {
        self.graph.n
    }

    fn num_edges(&self) -> usize {
        self.graph.indices.len() / 2
    }

    fn degree(&self, v: usize) -> usize {
        self.graph.degree(v)
    }

    fn neighbors_into(&self, v: usize, buf: &mut Vec<u32>) {
        buf.clear();
        buf.extend_from_slice(self.graph.neighbors(v));
    }
}

impl GraphStore for Dataset {
    fn name(&self) -> &str {
        &self.name
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn f_in(&self) -> usize {
        self.features.cols
    }

    fn split(&self) -> &Split {
        &self.split
    }

    fn gather_rows(&self, rows: &[u32], out: &mut Matrix) -> Result<()> {
        let f = self.features.cols;
        if out.rows != rows.len() || out.cols != f {
            *out = Matrix::zeros(rows.len(), f);
        }
        for (i, &gid) in rows.iter().enumerate() {
            anyhow::ensure!((gid as usize) < self.graph.n, "row {gid} out of range");
            out.row_mut(i).copy_from_slice(self.features.row(gid as usize));
        }
        Ok(())
    }

    fn gather_labels(&self, rows: &[u32], out: &mut Vec<u32>) -> Result<()> {
        out.clear();
        for &gid in rows {
            anyhow::ensure!((gid as usize) < self.graph.n, "row {gid} out of range");
            out.push(self.labels[gid as usize]);
        }
        Ok(())
    }

    fn backend(&self) -> &'static str {
        "resident"
    }

    fn adj(&self) -> &dyn Adjacency {
        self
    }
}

/// Fully in-memory backend wrapping a [`Dataset`] — the bitwise oracle.
pub struct ResidentStore {
    ds: Dataset,
}

impl ResidentStore {
    pub fn new(ds: Dataset) -> ResidentStore {
        ResidentStore { ds }
    }

    pub fn dataset(&self) -> &Dataset {
        &self.ds
    }
}

impl Adjacency for ResidentStore {
    fn n_nodes(&self) -> usize {
        self.ds.graph.n
    }

    fn num_edges(&self) -> usize {
        self.ds.graph.indices.len() / 2
    }

    fn degree(&self, v: usize) -> usize {
        self.ds.graph.degree(v)
    }

    fn neighbors_into(&self, v: usize, buf: &mut Vec<u32>) {
        buf.clear();
        buf.extend_from_slice(self.ds.graph.neighbors(v));
    }
}

impl GraphStore for ResidentStore {
    fn name(&self) -> &str {
        &self.ds.name
    }

    fn classes(&self) -> usize {
        self.ds.classes
    }

    fn f_in(&self) -> usize {
        self.ds.features.cols
    }

    fn split(&self) -> &Split {
        &self.ds.split
    }

    fn gather_rows(&self, rows: &[u32], out: &mut Matrix) -> Result<()> {
        self.ds.gather_rows(rows, out)
    }

    fn gather_labels(&self, rows: &[u32], out: &mut Vec<u32>) -> Result<()> {
        self.ds.gather_labels(rows, out)
    }

    fn backend(&self) -> &'static str {
        "resident"
    }

    fn adj(&self) -> &dyn Adjacency {
        self
    }
}

/// Read-only memory mapping of an entire file (raw `mmap(2)`; the crate
/// vendors no FFI helpers, so the two syscalls are declared directly).
#[cfg(unix)]
mod map {
    use std::ffi::{c_int, c_void};
    use std::fs::File;
    use std::os::unix::io::AsRawFd;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    const PROT_READ: c_int = 1;
    const MAP_PRIVATE: c_int = 2;

    pub struct Map {
        ptr: *mut c_void,
        len: usize,
    }

    // The mapping is immutable (PROT_READ) for its whole lifetime.
    unsafe impl Send for Map {}
    unsafe impl Sync for Map {}

    impl Map {
        pub fn of(file: &File, len: usize) -> std::io::Result<Map> {
            if len == 0 {
                return Ok(Map { ptr: std::ptr::null_mut(), len: 0 });
            }
            let ptr = unsafe {
                mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, file.as_raw_fd(), 0)
            };
            if ptr as isize == -1 {
                return Err(std::io::Error::last_os_error());
            }
            Ok(Map { ptr, len })
        }

        pub fn bytes(&self) -> &[u8] {
            if self.len == 0 {
                &[]
            } else {
                unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
            }
        }

        pub fn len(&self) -> usize {
            self.len
        }
    }

    impl Drop for Map {
        fn drop(&mut self) {
            if self.len != 0 {
                unsafe {
                    munmap(self.ptr, self.len);
                }
            }
        }
    }
}

/// Portable fallback: load the file into memory (no mmap off unix).
#[cfg(not(unix))]
mod map {
    use std::fs::File;
    use std::io::Read;

    pub struct Map {
        data: Vec<u8>,
    }

    impl Map {
        pub fn of(file: &File, len: usize) -> std::io::Result<Map> {
            let mut data = vec![0u8; len];
            let mut r: &File = file;
            r.read_exact(&mut data)?;
            Ok(Map { data })
        }

        pub fn bytes(&self) -> &[u8] {
            &self.data
        }

        pub fn len(&self) -> usize {
            self.data.len()
        }
    }
}

#[cfg(unix)]
fn read_at(f: &File, buf: &mut [u8], off: u64) -> std::io::Result<()> {
    std::os::unix::fs::FileExt::read_exact_at(f, buf, off)
}

#[cfg(not(unix))]
fn read_at(f: &File, buf: &mut [u8], off: u64) -> std::io::Result<()> {
    use std::io::{Read, Seek, SeekFrom};
    let mut r: &File = f;
    r.seek(SeekFrom::Start(off))?;
    r.read_exact(buf)
}

/// Out-of-core backend over the sharded v2 format.
///
/// Adjacency segments are memory-mapped and decoded per access; feature
/// rows are fetched with positioned reads so only rows a run actually
/// gathers are ever paged into the process (the kernel's page cache holds
/// the rest and is not charged to our RSS).  Labels and split masks are
/// tiny and load eagerly.
pub struct MmapStore {
    name: String,
    n: usize,
    classes: usize,
    f_in: usize,
    num_edges: usize,
    indptr: map::Map,
    indices: map::Map,
    labels: Vec<u32>,
    split: Split,
    rows_per_shard: usize,
    shards: Vec<File>,
    dir: PathBuf,
    content_hash: u64,
}

impl MmapStore {
    /// Open a shard directory, verifying every file's size and FNV
    /// content hash against the manifest before trusting any byte.
    pub fn open(dir: &Path) -> Result<MmapStore> {
        let manifest = ShardManifest::load(dir)?;
        // Streaming verification: a fixed 64 KiB buffer keeps the check
        // RSS-flat even when feature shards dwarf memory.
        let mut buf = vec![0u8; 64 * 1024];
        for f in &manifest.files {
            let path = dir.join(&f.path);
            let meta = std::fs::metadata(&path)
                .map_err(|e| anyhow::anyhow!("shard file {path:?} missing: {e}"))?;
            anyhow::ensure!(
                meta.len() == f.bytes,
                "shard file {:?} is {} bytes, manifest says {}",
                f.path,
                meta.len(),
                f.bytes
            );
            let mut h = Fnv::new();
            let mut r = File::open(&path)?;
            loop {
                let k = std::io::Read::read(&mut r, &mut buf)?;
                if k == 0 {
                    break;
                }
                h.update(&buf[..k]);
            }
            anyhow::ensure!(
                h.finish() == f.hash,
                "shard file {:?} content hash mismatch (corrupt or stale shards; \
                 rebuild with `varco dataset build --format shard`)",
                f.path
            );
        }

        let n = manifest.n;
        let open_map = |name: &str, want: u64| -> Result<map::Map> {
            let file = File::open(dir.join(name))?;
            let m = map::Map::of(&file, want as usize)?;
            Ok(m)
        };
        let indptr = open_map("indptr.bin", ((n + 1) * 8) as u64)?;
        let last = {
            let b = indptr.bytes();
            let o = n * 8;
            u64::from_le_bytes(b[o..o + 8].try_into().unwrap())
        };
        anyhow::ensure!(
            last as usize == manifest.num_edges * 2,
            "indptr tail {last} disagrees with manifest edge count {}",
            manifest.num_edges
        );
        let indices = open_map("indices.bin", last * 4)?;

        let labels_file = File::open(dir.join("labels.bin"))?;
        let mut lbytes = vec![0u8; n * 4];
        read_at(&labels_file, &mut lbytes, 0)?;
        let labels: Vec<u32> =
            lbytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect();
        anyhow::ensure!(
            labels.iter().all(|&y| (y as usize) < manifest.classes),
            "label out of range in shards"
        );

        let split_file = File::open(dir.join("split.bin"))?;
        let mut sbytes = vec![0u8; n];
        read_at(&split_file, &mut sbytes, 0)?;
        let split = Split {
            train: sbytes.iter().map(|&b| b & 1 != 0).collect(),
            val: sbytes.iter().map(|&b| b & 2 != 0).collect(),
            test: sbytes.iter().map(|&b| b & 4 != 0).collect(),
        };

        let mut shards = Vec::new();
        for f in &manifest.files {
            if f.path.starts_with("features_") {
                shards.push(File::open(dir.join(&f.path))?);
            }
        }
        anyhow::ensure!(!shards.is_empty() || n == 0, "manifest lists no feature shards");
        let expect_shards = if n == 0 { 0 } else { (n + manifest.rows_per_shard - 1) / manifest.rows_per_shard };
        anyhow::ensure!(
            shards.len() == expect_shards,
            "manifest lists {} feature shards, expected {expect_shards}",
            shards.len()
        );

        Ok(MmapStore {
            name: manifest.name.clone(),
            n,
            classes: manifest.classes,
            f_in: manifest.f_in,
            num_edges: manifest.num_edges,
            indptr,
            indices,
            labels,
            split,
            rows_per_shard: manifest.rows_per_shard,
            shards,
            dir: dir.to_path_buf(),
            content_hash: manifest.content_hash(),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    #[inline]
    fn ip(&self, i: usize) -> u64 {
        let b = self.indptr.bytes();
        let o = i * 8;
        u64::from_le_bytes(b[o..o + 8].try_into().unwrap())
    }
}

impl Adjacency for MmapStore {
    fn n_nodes(&self) -> usize {
        self.n
    }

    fn num_edges(&self) -> usize {
        self.num_edges
    }

    fn degree(&self, v: usize) -> usize {
        (self.ip(v + 1) - self.ip(v)) as usize
    }

    fn neighbors_into(&self, v: usize, buf: &mut Vec<u32>) {
        buf.clear();
        let lo = self.ip(v) as usize;
        let hi = self.ip(v + 1) as usize;
        let b = self.indices.bytes();
        buf.reserve(hi - lo);
        for k in lo..hi {
            let o = k * 4;
            buf.push(u32::from_le_bytes(b[o..o + 4].try_into().unwrap()));
        }
    }
}

impl GraphStore for MmapStore {
    fn name(&self) -> &str {
        &self.name
    }

    fn classes(&self) -> usize {
        self.classes
    }

    fn f_in(&self) -> usize {
        self.f_in
    }

    fn split(&self) -> &Split {
        &self.split
    }

    fn gather_rows(&self, rows: &[u32], out: &mut Matrix) -> Result<()> {
        if out.rows != rows.len() || out.cols != self.f_in {
            *out = Matrix::zeros(rows.len(), self.f_in);
        }
        let stride = self.f_in * 4;
        let mut bytes = vec![0u8; stride];
        for (i, &gid) in rows.iter().enumerate() {
            let g = gid as usize;
            anyhow::ensure!(g < self.n, "row {gid} out of range");
            let shard = g / self.rows_per_shard;
            let row_in = g % self.rows_per_shard;
            read_at(&self.shards[shard], &mut bytes, (row_in * stride) as u64)?;
            for (dst, c) in out.row_mut(i).iter_mut().zip(bytes.chunks_exact(4)) {
                *dst = f32::from_le_bytes(c.try_into().unwrap());
            }
        }
        Ok(())
    }

    fn gather_labels(&self, rows: &[u32], out: &mut Vec<u32>) -> Result<()> {
        out.clear();
        for &gid in rows {
            anyhow::ensure!((gid as usize) < self.n, "row {gid} out of range");
            out.push(self.labels[gid as usize]);
        }
        Ok(())
    }

    fn backend(&self) -> &'static str {
        "mmap"
    }

    fn shard_summary(&self) -> Option<ShardSummary> {
        Some(ShardSummary {
            shards: self.shards.len(),
            mapped_bytes: self.indptr.len() + self.indices.len(),
            content_hash: self.content_hash,
        })
    }

    fn adj(&self) -> &dyn Adjacency {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::io::write_shards;
    use crate::util::testing::TempDir;

    fn shard_fixture(rows_per_shard: usize) -> (TempDir, Dataset) {
        let ds = Dataset::load("karate-like", 0, 5).unwrap();
        let dir = TempDir::new().unwrap();
        write_shards(&ds, dir.path(), rows_per_shard).unwrap();
        (dir, ds)
    }

    #[test]
    fn mmap_store_matches_resident_bitwise() {
        let (dir, ds) = shard_fixture(10);
        let ms = MmapStore::open(dir.path()).unwrap();
        assert_eq!(ms.n_nodes(), ds.n());
        assert_eq!(Adjacency::num_edges(&ms), ds.graph.num_edges());
        assert_eq!(ms.classes(), ds.classes);
        assert_eq!(ms.f_in(), ds.f_in());
        assert_eq!(ms.split(), &ds.split);
        let mut a = Vec::new();
        let mut b = Vec::new();
        for v in 0..ds.n() {
            assert_eq!(Adjacency::degree(&ms, v), ds.graph.degree(v), "degree {v}");
            ms.neighbors_into(v, &mut a);
            ds.neighbors_into(v, &mut b);
            assert_eq!(a, b, "neighbors {v}");
        }
        // gather in shard-crossing and reversed orders
        let rows: Vec<u32> = (0..ds.n() as u32).rev().collect();
        let mut xm = Matrix::zeros(0, 0);
        let mut xr = Matrix::zeros(0, 0);
        ms.gather_rows(&rows, &mut xm).unwrap();
        ds.gather_rows(&rows, &mut xr).unwrap();
        assert_eq!(xm.data.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                   xr.data.iter().map(|f| f.to_bits()).collect::<Vec<_>>());
        let mut lm = Vec::new();
        let mut lr = Vec::new();
        ms.gather_labels(&rows, &mut lm).unwrap();
        ds.gather_labels(&rows, &mut lr).unwrap();
        assert_eq!(lm, lr);
    }

    #[test]
    fn shard_summary_reports_counts() {
        let (dir, ds) = shard_fixture(10);
        let ms = MmapStore::open(dir.path()).unwrap();
        let s = ms.shard_summary().unwrap();
        assert_eq!(s.shards, (ds.n() + 9) / 10);
        assert_eq!(s.mapped_bytes, (ds.n() + 1) * 8 + ds.graph.indices.len() * 4);
        assert_eq!(ms.backend(), "mmap");
        assert_eq!(ds.backend(), "resident");
        assert!(ds.shard_summary().is_none());
    }

    #[test]
    fn bit_flip_in_any_shard_file_is_rejected() {
        let (dir, _) = shard_fixture(16);
        // flip one bit in the middle of the second feature shard
        let victim = dir.path().join("features_0001.bin");
        let mut bytes = std::fs::read(&victim).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&victim, &bytes).unwrap();
        let err = MmapStore::open(dir.path()).unwrap_err();
        assert!(err.to_string().contains("hash mismatch"), "{err}");
    }

    #[test]
    fn truncated_shard_file_is_rejected() {
        let (dir, _) = shard_fixture(16);
        let victim = dir.path().join("indices.bin");
        let bytes = std::fs::read(&victim).unwrap();
        std::fs::write(&victim, &bytes[..bytes.len() - 4]).unwrap();
        let err = MmapStore::open(dir.path()).unwrap_err();
        assert!(err.to_string().contains("bytes"), "{err}");
    }

    #[test]
    fn missing_shard_file_is_rejected() {
        let (dir, _) = shard_fixture(16);
        std::fs::remove_file(dir.path().join("labels.bin")).unwrap();
        let err = MmapStore::open(dir.path()).unwrap_err();
        assert!(err.to_string().contains("missing"), "{err}");
    }
}
