//! Compressed sparse row graph storage (undirected, unweighted).

/// Undirected graph in CSR form.  Neighbor lists are sorted; no self-loops,
/// no parallel edges.  `indptr.len() == n + 1`, `indices.len() == 2m`.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub n: usize,
    pub indptr: Vec<u64>,
    pub indices: Vec<u32>,
}

impl Csr {
    /// Build from an undirected edge list; dedups, drops self-loops,
    /// symmetrizes.
    ///
    /// Two-pass counting-sort construction: a degree histogram sizes one
    /// flat index array, a second pass bucket-fills it, then each row is
    /// sorted and deduped in place.  This replaces the old per-node
    /// `Vec<Vec<u32>>` adjacency (one heap allocation per node) with three
    /// flat allocations total, which is what large generated graphs spend
    /// their build time on.
    pub fn from_edges(n: usize, edges: &[(u32, u32)]) -> Csr {
        // pass 1: degree histogram (self-loops dropped, duplicates kept
        // for now), offset by one slot for the in-place prefix sum
        let mut indptr = vec![0u64; n + 1];
        for &(u, v) in edges {
            if u == v {
                continue;
            }
            assert!((u as usize) < n && (v as usize) < n, "edge ({u},{v}) out of range n={n}");
            indptr[u as usize + 1] += 1;
            indptr[v as usize + 1] += 1;
        }
        for i in 1..=n {
            indptr[i] += indptr[i - 1];
        }
        // pass 2: bucket fill at each row's write cursor
        let mut indices = vec![0u32; indptr[n] as usize];
        let mut cursor: Vec<u64> = indptr[..n].to_vec();
        for &(u, v) in edges {
            if u == v {
                continue;
            }
            indices[cursor[u as usize] as usize] = v;
            cursor[u as usize] += 1;
            indices[cursor[v as usize] as usize] = u;
            cursor[v as usize] += 1;
        }
        // per-row sort + dedup, compacting the flat array in place (the
        // write head never overtakes the row being read: write <= lo)
        let mut write = 0usize;
        let mut out_indptr = Vec::with_capacity(n + 1);
        out_indptr.push(0u64);
        for u in 0..n {
            let lo = indptr[u] as usize;
            let hi = indptr[u + 1] as usize;
            indices[lo..hi].sort_unstable();
            let mut prev = None;
            for k in lo..hi {
                let v = indices[k];
                if prev != Some(v) {
                    indices[write] = v;
                    write += 1;
                    prev = Some(v);
                }
            }
            out_indptr.push(write as u64);
        }
        indices.truncate(write);
        Csr { n, indptr: out_indptr, indices }
    }

    /// Number of undirected edges.
    pub fn num_edges(&self) -> usize {
        self.indices.len() / 2
    }

    #[inline]
    pub fn neighbors(&self, u: usize) -> &[u32] {
        &self.indices[self.indptr[u] as usize..self.indptr[u + 1] as usize]
    }

    #[inline]
    pub fn degree(&self, u: usize) -> usize {
        (self.indptr[u + 1] - self.indptr[u]) as usize
    }

    pub fn degrees(&self) -> Vec<u32> {
        (0..self.n).map(|u| self.degree(u) as u32).collect()
    }

    pub fn avg_degree(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.indices.len() as f64 / self.n as f64
    }

    pub fn has_edge(&self, u: usize, v: usize) -> bool {
        self.neighbors(u).binary_search(&(v as u32)).is_ok()
    }

    /// Structural invariants; used by tests and after IO round trips.
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(self.indptr.len() == self.n + 1, "indptr length");
        anyhow::ensure!(
            *self.indptr.last().unwrap_or(&0) as usize == self.indices.len(),
            "indptr tail != indices len"
        );
        for u in 0..self.n {
            anyhow::ensure!(self.indptr[u] <= self.indptr[u + 1], "indptr not monotone at {u}");
            let nb = self.neighbors(u);
            for w in nb.windows(2) {
                anyhow::ensure!(w[0] < w[1], "neighbors of {u} not strictly sorted");
            }
            for &v in nb {
                anyhow::ensure!((v as usize) < self.n, "neighbor {v} out of range");
                anyhow::ensure!(v as usize != u, "self-loop at {u}");
                anyhow::ensure!(self.has_edge(v as usize, u), "asymmetric edge {u}->{v}");
            }
        }
        Ok(())
    }

    /// Connected-component count (BFS) — used by generator tests.
    pub fn num_components(&self) -> usize {
        let mut seen = vec![false; self.n];
        let mut count = 0;
        let mut queue = std::collections::VecDeque::new();
        for s in 0..self.n {
            if seen[s] {
                continue;
            }
            count += 1;
            seen[s] = true;
            queue.push_back(s);
            while let Some(u) = queue.pop_front() {
                for &v in self.neighbors(u) {
                    if !seen[v as usize] {
                        seen[v as usize] = true;
                        queue.push_back(v as usize);
                    }
                }
            }
        }
        count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_edges_symmetrizes_and_dedups() {
        let g = Csr::from_edges(4, &[(0, 1), (1, 0), (1, 2), (2, 2), (3, 1)]);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.neighbors(1), &[0, 2, 3]);
        assert!(g.has_edge(2, 1) && !g.has_edge(2, 2));
        g.validate().unwrap();
    }

    #[test]
    fn degrees_and_avg() {
        let g = Csr::from_edges(3, &[(0, 1), (1, 2)]);
        assert_eq!(g.degrees(), vec![1, 2, 1]);
        assert!((g.avg_degree() - 4.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_graph_is_valid() {
        let g = Csr::from_edges(5, &[]);
        assert_eq!(g.num_edges(), 0);
        g.validate().unwrap();
        assert_eq!(g.num_components(), 5);
    }

    #[test]
    fn components_counted() {
        let g = Csr::from_edges(6, &[(0, 1), (1, 2), (3, 4)]);
        assert_eq!(g.num_components(), 3);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_edge_panics() {
        Csr::from_edges(2, &[(0, 5)]);
    }

    #[test]
    fn counting_sort_build_matches_naive_reference() {
        // random multigraph with duplicate edges and self-loops
        let mut rng = crate::util::Rng::new(42);
        let n = 50usize;
        let edges: Vec<(u32, u32)> = (0..400)
            .map(|_| (rng.next_below(n) as u32, rng.next_below(n) as u32))
            .collect();
        let g = Csr::from_edges(n, &edges);
        g.validate().unwrap();
        // the old per-node adjacency build, kept as the oracle
        let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        for &(u, v) in &edges {
            if u == v {
                continue;
            }
            adj[u as usize].push(v);
            adj[v as usize].push(u);
        }
        for (u, list) in adj.iter_mut().enumerate() {
            list.sort_unstable();
            list.dedup();
            assert_eq!(g.neighbors(u), &list[..], "row {u}");
        }
    }
}
