//! HLO-text static analysis: op census over the AOT artifacts.
//!
//! This is the L2 profiling tool of the §Perf pass (no runtime profiler
//! exists for the PJRT CPU plugin here): it verifies the lowered graphs
//! contain no redundant recomputation (dot counts match the model's
//! algebra), quantifies the Pallas-interpret `while` loops, and estimates
//! FLOPs per artifact from the dot shapes.

use crate::Result;
use std::collections::BTreeMap;

/// Census of one HLO module.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HloStats {
    /// parameters of the ENTRY computation only
    pub parameters: usize,
    pub dots: usize,
    pub while_loops: usize,
    pub dynamic_slices: usize,
    pub broadcasts: usize,
    pub total_instructions: usize,
    /// multiply-add FLOPs from dot shapes (2*M*N*K each)
    pub dot_flops: u64,
    pub op_counts: BTreeMap<String, usize>,
}

/// One parsed instruction line: `name = type[dims]... op(args...)`.
struct Instr<'a> {
    name: &'a str,
    dims: Vec<u64>,
    op: &'a str,
    args: Vec<&'a str>,
    line: &'a str,
}

fn parse_instr(line: &str) -> Option<Instr<'_>> {
    let trimmed = line.trim_start();
    let body = trimmed.strip_prefix("ROOT ").unwrap_or(trimmed);
    let (name, rhs) = body.split_once(" = ")?;
    // shape token is everything up to the first space after '='
    let (shape_tok, rest) = rhs.split_once(' ')?;
    let op = rest.split(|c: char| c == '(' || c == ' ' || c == ',').next()?;
    if op.is_empty() || !op.chars().next()?.is_ascii_alphabetic() || op.contains('[') {
        // tuple-typed shape tokens contain spaces; skip mis-splits
        return None;
    }
    let args = rest
        .split_once('(')
        .map(|(_, a)| {
            a.split(')')
                .next()
                .unwrap_or("")
                .split(',')
                .map(|s| s.trim())
                .filter(|s| !s.is_empty())
                .collect()
        })
        .unwrap_or_default();
    Some(Instr { name, dims: parse_dims(shape_tok), op, args, line })
}

fn parse_dims(s: &str) -> Vec<u64> {
    let Some(open) = s.find('[') else { return vec![] };
    let Some(close) = s[open..].find(']') else { return vec![] };
    s[open + 1..open + close]
        .split(',')
        .filter_map(|d| d.trim().parse().ok())
        .collect()
}

/// Parse HLO text emitted by the AOT pipeline.
pub fn analyze(text: &str) -> Result<HloStats> {
    let mut stats = HloStats::default();
    // pass 1: shapes of every named instruction (for dot operand lookup)
    let mut shapes: BTreeMap<&str, Vec<u64>> = BTreeMap::new();
    for line in text.lines() {
        if let Some(i) = parse_instr(line) {
            shapes.insert(i.name, i.dims.clone());
        }
    }
    // pass 2: census; ENTRY parameters tracked by section
    let mut in_entry = false;
    for line in text.lines() {
        if line.starts_with("ENTRY ") {
            in_entry = true;
        } else if line.starts_with('}') {
            in_entry = false;
        }
        let Some(i) = parse_instr(line) else { continue };
        stats.total_instructions += 1;
        *stats.op_counts.entry(i.op.to_string()).or_insert(0) += 1;
        match i.op {
            "parameter" if in_entry => stats.parameters += 1,
            "dot" => {
                stats.dots += 1;
                stats.dot_flops += dot_flops(&i, &shapes);
            }
            "while" => stats.while_loops += 1,
            "dynamic-slice" => stats.dynamic_slices += 1,
            "broadcast" => stats.broadcasts += 1,
            _ => {}
        }
    }
    anyhow::ensure!(stats.total_instructions > 0, "no instructions parsed — not HLO text?");
    Ok(stats)
}

/// 2*M*N*K via output shape and the lhs contracted dimension.
fn dot_flops(i: &Instr, shapes: &BTreeMap<&str, Vec<u64>>) -> u64 {
    let out: u64 = i.dims.iter().product();
    let Some(lhs) = i.args.first().and_then(|a| shapes.get(a)) else { return 0 };
    // contracted dim index from "lhs_contracting_dims={d}"
    let k = i
        .line
        .split("lhs_contracting_dims={")
        .nth(1)
        .and_then(|s| s.split('}').next())
        .and_then(|d| d.split(',').next())
        .and_then(|d| d.trim().parse::<usize>().ok())
        .and_then(|d| lhs.get(d).copied())
        .unwrap_or(0);
    2 * out * k
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
HloModule jit_fn

relu_helper {
  x = f32[4,16]{1,0} parameter(0)
  ROOT m = f32[4,16]{1,0} maximum(x, x)
}

ENTRY main {
  p0 = f32[4,8]{1,0} parameter(0)
  p1 = f32[8,16]{1,0} parameter(1)
  dot.1 = f32[4,16]{1,0} dot(p0, p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  b = f32[4,16]{1,0} broadcast(c), dimensions={}
  ROOT t = (f32[4,16]{1,0}) tuple(dot.1)
}
"#;

    #[test]
    fn counts_entry_parameters_only() {
        let s = analyze(SAMPLE).unwrap();
        assert_eq!(s.parameters, 2, "{s:?}");
        assert_eq!(s.dots, 1);
        assert_eq!(s.broadcasts, 1);
        assert_eq!(s.op_counts["maximum"], 1);
    }

    #[test]
    fn dot_flops_via_operand_lookup() {
        let s = analyze(SAMPLE).unwrap();
        assert_eq!(s.dot_flops, 2 * (4 * 16) * 8);
    }

    #[test]
    fn garbage_rejected() {
        assert!(analyze("not hlo at all").is_err());
    }

    #[test]
    fn analyzes_real_artifacts_if_present() {
        let path = std::path::Path::new("artifacts/quickstart/layer0_forward.hlo.txt");
        if !path.exists() {
            return; // covered through `make test`
        }
        let text = std::fs::read_to_string(path).unwrap();
        let s = analyze(&text).unwrap();
        assert_eq!(s.parameters, 7, "{s:?}");
        // two weight dots + the pallas aggregation (unrolled at this size)
        assert!(s.dots >= 2, "{s:?}");
        assert!(s.dot_flops > 0);
        // the pallas-interpret grid leaves its tile plumbing signature:
        // dynamic-slice / dynamic-update-slice per HBM<->VMEM move
        assert!(s.dynamic_slices >= 1, "{s:?}");
    }
}
