//! PJRT runtime: load AOT HLO-text artifacts (built by `make artifacts`)
//! and execute them on the CPU PJRT client.
//!
//! Interchange is HLO **text** — jax >= 0.5 emits serialized protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod hlo_stats;
pub mod minibatch;

#[cfg(feature = "pjrt")]
use crate::tensor::Matrix;
use crate::util::Json;
use crate::Result;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

pub const MANIFEST_VERSION: u64 = 2;

/// One shape config from the manifest (mirrors python ShapeConfig).
#[derive(Clone, Debug, PartialEq)]
pub struct ManifestConfig {
    pub tag: String,
    pub n_total: usize,
    pub q: usize,
    pub n_local: usize,
    pub n_bnd: usize,
    pub f_in: usize,
    pub hidden: usize,
    pub classes: usize,
    pub layers: usize,
    pub param_count: usize,
    /// artifact name -> file name
    pub files: BTreeMap<String, String>,
}

impl ManifestConfig {
    pub fn model_dims(&self) -> crate::engine::ModelDims {
        crate::engine::ModelDims {
            f_in: self.f_in,
            hidden: self.hidden,
            classes: self.classes,
            layers: self.layers,
        }
    }
}

/// Parsed artifacts/manifest.json.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub configs: BTreeMap<String, ManifestConfig>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {path:?}: {e}; run `make artifacts`"))?;
        let j = Json::parse(&text)?;
        let version = j.require("version")?.as_usize().unwrap_or(0) as u64;
        anyhow::ensure!(
            version == MANIFEST_VERSION,
            "manifest version {version} != {MANIFEST_VERSION}; re-run `make artifacts`"
        );
        let mut configs = BTreeMap::new();
        for (tag, cfg) in j.require("configs")?.as_obj().into_iter().flatten() {
            let u = |k: &str| -> Result<usize> {
                cfg.require(k)?
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("{tag}.{k} not a usize"))
            };
            let mut files = BTreeMap::new();
            for (name, art) in cfg.require("artifacts")?.as_obj().into_iter().flatten() {
                files.insert(
                    name.clone(),
                    art.require("file")?
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("{tag}.{name}.file"))?
                        .to_string(),
                );
            }
            configs.insert(
                tag.clone(),
                ManifestConfig {
                    tag: tag.clone(),
                    n_total: u("n_total")?,
                    q: u("q")?,
                    n_local: u("n_local")?,
                    n_bnd: u("n_bnd")?,
                    f_in: u("f_in")?,
                    hidden: u("hidden")?,
                    classes: u("classes")?,
                    layers: u("layers")?,
                    param_count: u("param_count")?,
                    files,
                },
            );
        }
        Ok(Manifest { root: dir.to_path_buf(), configs })
    }

    pub fn config(&self, tag: &str) -> Result<&ManifestConfig> {
        self.configs.get(tag).ok_or_else(|| {
            anyhow::anyhow!(
                "config {tag:?} not in manifest (have: {:?}); add it to python/compile/shapes.py and re-run `make artifacts`",
                self.configs.keys().collect::<Vec<_>>()
            )
        })
    }
}

/// A compiled executable plus its expected output arity.
#[cfg(feature = "pjrt")]
pub struct Artifact {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl Artifact {
    /// Execute with literal inputs; unpacks the tuple output.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("{}: execute failed: {e:?}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{}: to_literal: {e:?}", self.name))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("{}: to_tuple: {e:?}", self.name))
    }

    /// Execute with device-resident buffers (hot path: static operands like
    /// the adjacency blocks are uploaded once and reused every epoch).
    pub fn run_b(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute_b(inputs)
            .map_err(|e| anyhow::anyhow!("{}: execute_b failed: {e:?}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{}: to_literal: {e:?}", self.name))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("{}: to_tuple: {e:?}", self.name))
    }

    /// The PJRT client this executable was compiled for.
    pub fn client(&self) -> &xla::PjRtClient {
        self.exe.client()
    }
}

/// Upload a matrix to the device.
#[cfg(feature = "pjrt")]
pub fn buffer_from_matrix(client: &xla::PjRtClient, m: &Matrix) -> Result<xla::PjRtBuffer> {
    client
        .buffer_from_host_buffer(&m.data, &[m.rows, m.cols], None)
        .map_err(|e| anyhow::anyhow!("buffer upload: {e:?}"))
}

/// Upload a vector to the device.
#[cfg(feature = "pjrt")]
pub fn buffer_from_vec(client: &xla::PjRtClient, v: &[f32]) -> Result<xla::PjRtBuffer> {
    client
        .buffer_from_host_buffer(v, &[v.len()], None)
        .map_err(|e| anyhow::anyhow!("buffer upload: {e:?}"))
}

/// Upload labels as i32.
#[cfg(feature = "pjrt")]
pub fn buffer_from_labels(client: &xla::PjRtClient, labels: &[u32]) -> Result<xla::PjRtBuffer> {
    let as_i32: Vec<i32> = labels.iter().map(|&x| x as i32).collect();
    client
        .buffer_from_host_buffer(&as_i32, &[as_i32.len()], None)
        .map_err(|e| anyhow::anyhow!("buffer upload: {e:?}"))
}

/// All executables for one shape config.
#[cfg(feature = "pjrt")]
pub struct ArtifactSet {
    pub cfg: ManifestConfig,
    pub layer_forward: Vec<Artifact>,
    pub layer_backward: Vec<Artifact>,
    pub loss_grad: Artifact,
}

/// PJRT client + artifact loader.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile_file(&self, path: &Path, name: &str) -> Result<Artifact> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        Ok(Artifact { name: name.to_string(), exe })
    }

    /// Load + compile every artifact of a config.
    pub fn load_config(&self, manifest: &Manifest, tag: &str) -> Result<ArtifactSet> {
        let cfg = manifest.config(tag)?.clone();
        let dir = manifest.root.join(tag);
        let get = |name: &str| -> Result<Artifact> {
            let file = cfg
                .files
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("artifact {name} missing from manifest"))?;
            self.compile_file(&dir.join(file), name)
        };
        let mut layer_forward = Vec::new();
        let mut layer_backward = Vec::new();
        for l in 0..cfg.layers {
            layer_forward.push(get(&format!("layer{l}_forward"))?);
            layer_backward.push(get(&format!("layer{l}_backward"))?);
        }
        let loss_grad = get("loss_grad")?;
        Ok(ArtifactSet { cfg, layer_forward, layer_backward, loss_grad })
    }
}

// ----------------- literal <-> tensor marshalling -----------------

/// f32 matrix -> rank-2 literal.
#[cfg(feature = "pjrt")]
pub fn literal_from_matrix(m: &Matrix) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(&m.data)
        .reshape(&[m.rows as i64, m.cols as i64])
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))?)
}

/// f32 slice -> rank-1 literal.
#[cfg(feature = "pjrt")]
pub fn literal_from_vec(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// u32 labels -> i32 rank-1 literal.
#[cfg(feature = "pjrt")]
pub fn literal_from_labels(labels: &[u32]) -> xla::Literal {
    let as_i32: Vec<i32> = labels.iter().map(|&x| x as i32).collect();
    xla::Literal::vec1(&as_i32)
}

/// rank-2 f32 literal -> matrix.
#[cfg(feature = "pjrt")]
pub fn matrix_from_literal(lit: &xla::Literal) -> Result<Matrix> {
    let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("shape: {e:?}"))?;
    let dims = shape.dims();
    anyhow::ensure!(dims.len() == 2, "expected rank-2, got {dims:?}");
    let data = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?;
    Ok(Matrix::from_vec(dims[0] as usize, dims[1] as usize, data))
}

/// scalar f32 literal.
#[cfg(feature = "pjrt")]
pub fn scalar_from_literal(lit: &xla::Literal) -> Result<f32> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("to_vec: {e:?}"))?
        .first()
        .copied()
        .ok_or_else(|| anyhow::anyhow!("empty literal"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::TempDir;

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_matrix_roundtrip() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let lit = literal_from_matrix(&m).unwrap();
        let back = matrix_from_literal(&lit).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn manifest_parse_and_validation() {
        let dir = TempDir::new().unwrap();
        let text = r#"{
          "version": 2,
          "configs": {
            "t": {
              "tag": "t", "n_total": 8, "q": 2, "n_local": 4, "n_bnd": 4,
              "f_in": 3, "hidden": 5, "classes": 2, "layers": 3,
              "param_count": 99, "weight_shapes": [],
              "artifacts": {"layer0_forward": {"file": "f.hlo.txt", "inputs": [], "n_outputs": 3}}
            }
          }
        }"#;
        std::fs::write(dir.path().join("manifest.json"), text).unwrap();
        let m = Manifest::load(dir.path()).unwrap();
        let c = m.config("t").unwrap();
        assert_eq!(c.n_local, 4);
        assert_eq!(c.files["layer0_forward"], "f.hlo.txt");
        let err = m.config("missing").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn manifest_version_mismatch_rejected() {
        let dir = TempDir::new().unwrap();
        std::fs::write(dir.path().join("manifest.json"), r#"{"version": 1, "configs": {}}"#)
            .unwrap();
        let err = Manifest::load(dir.path()).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let dir = TempDir::new().unwrap();
        let err = Manifest::load(dir.path()).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
