//! Per-epoch mini-batch views for `mode = sampled`.
//!
//! Each training epoch draws one deterministic batch of training nodes,
//! expands it with per-layer fanout sampling, and materializes the
//! induced subgraph as a complete [`Dataset`] + [`Partition`] +
//! [`WorkerGraph`] stack — the same types the full-graph trainer runs
//! on.  Nothing downstream (send plans, wire codec, ledgers, rate
//! controllers) knows it is looking at a sample: the view is just a
//! smaller graph whose part assignment is inherited from the full-graph
//! partition, so every sampled node stays on the worker that owns it and
//! sampled halo exchanges travel the same links the full exchanges would.
//!
//! Determinism: the view is a pure function of
//! `(full dataset, assignment, q, sampling config, seed, epoch)` — no
//! RNG state carries across epochs — so the sequential, parallel, and
//! multi-process runtimes rebuild bit-identical views independently.

use crate::graph::sample::{draw_batch, induce, sample_nodes, SamplingConfig};
use crate::graph::store::GraphStore;
use crate::graph::{Dataset, Split};
use crate::partition::{Partition, WorkerGraph};
use crate::tensor::Matrix;
use crate::Result;

/// One epoch's sampled world: the induced dataset plus the restricted
/// partition and its worker graphs, ready for `RunSetup::build`.
pub struct MinibatchView {
    /// this epoch's training nodes (global ids, sorted)
    pub batch: Vec<u32>,
    /// every sampled node (global ids, sorted); local id in the view =
    /// position here, so `nodes[local]` maps view rows back to the full
    /// graph (the historical cache keys its rows by these global ids)
    pub nodes: Vec<u32>,
    pub dataset: Dataset,
    pub partition: Partition,
    pub worker_graphs: Vec<WorkerGraph>,
}

/// Build epoch `epoch`'s view.  `assignment` is the *full-graph* part
/// assignment; the view restricts it to the sampled nodes (unbalanced —
/// a batch rarely covers every part equally).
pub fn build_view(
    full: &dyn GraphStore,
    assignment: &[u32],
    q: usize,
    sampling: &SamplingConfig,
    seed: u64,
    epoch: usize,
) -> Result<MinibatchView> {
    anyhow::ensure!(assignment.len() == full.n_nodes(), "assignment size mismatch");
    let batch = draw_batch(&full.split().train, sampling.batch_size, seed, epoch);
    anyhow::ensure!(!batch.is_empty(), "dataset {} has no training nodes to sample", full.name());
    let nodes = sample_nodes(full.adj(), &batch, &sampling.fanouts, seed, epoch);
    let graph = induce(full.adj(), &nodes);

    // gather only the sampled rows — with an out-of-core store this (not
    // the full n x f matrix) is all that ever becomes resident
    let mut features = Matrix::zeros(0, 0);
    full.gather_rows(&nodes, &mut features)?;
    let mut labels = Vec::with_capacity(nodes.len());
    full.gather_labels(&nodes, &mut labels)?;
    // only batch nodes train on the view; sampled support nodes exist to
    // feed aggregation, and eval stays on the full graph
    let mut train = vec![false; nodes.len()];
    for (local, &gid) in nodes.iter().enumerate() {
        train[local] = batch.binary_search(&gid).is_ok();
    }
    let dataset = Dataset {
        name: full.name().to_string(),
        graph,
        features,
        labels,
        classes: full.classes(),
        split: Split { train, val: vec![false; nodes.len()], test: vec![false; nodes.len()] },
    };

    let local_assignment: Vec<u32> = nodes.iter().map(|&gid| assignment[gid as usize]).collect();
    let partition = Partition::new_unbalanced(q, local_assignment)?;
    let worker_graphs = WorkerGraph::build_all(&dataset.graph, &partition)?;
    Ok(MinibatchView { batch, nodes, dataset, partition, worker_graphs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Fanout;

    fn cfg(batch_size: usize, fanouts: Vec<Fanout>) -> SamplingConfig {
        SamplingConfig { batch_size, fanouts }
    }

    fn karate() -> Dataset {
        Dataset::load("karate-like", 0, 7).unwrap()
    }

    #[test]
    fn views_are_pure_functions_of_seed_and_epoch() {
        let ds = karate();
        let assign: Vec<u32> = (0..ds.n() as u32).map(|i| i % 2).collect();
        let sc = cfg(8, vec![Fanout::Limit(3), Fanout::Limit(3)]);
        let a = build_view(&ds, &assign, 2, &sc, 11, 4).unwrap();
        let b = build_view(&ds, &assign, 2, &sc, 11, 4).unwrap();
        assert_eq!(a.batch, b.batch);
        assert_eq!(a.nodes, b.nodes);
        assert_eq!(a.dataset.features.data, b.dataset.features.data);
        assert_eq!(a.partition.assignment, b.partition.assignment);
        // different epochs sample different views
        let c = build_view(&ds, &assign, 2, &sc, 11, 5).unwrap();
        assert_ne!(a.batch, c.batch);
    }

    #[test]
    fn view_gathers_rows_and_marks_only_the_batch_as_train() {
        let ds = karate();
        let assign: Vec<u32> = (0..ds.n() as u32).map(|i| i % 2).collect();
        let v = build_view(&ds, &assign, 2, &cfg(4, vec![Fanout::All]), 3, 0).unwrap();
        assert_eq!(v.dataset.n(), v.nodes.len());
        assert_eq!(v.worker_graphs.len(), 2);
        let n_train = v.dataset.split.train.iter().filter(|&&t| t).count();
        assert_eq!(n_train, v.batch.len());
        assert_eq!(v.batch.len(), 4);
        for (local, &gid) in v.nodes.iter().enumerate() {
            let g = gid as usize;
            assert_eq!(v.dataset.features.row(local), ds.features.row(g), "row gather");
            assert_eq!(v.dataset.labels[local], ds.labels[g]);
            assert_eq!(v.partition.assignment[local], assign[g], "ownership inherited");
            assert_eq!(
                v.dataset.split.train[local],
                v.batch.binary_search(&gid).is_ok(),
                "train = batch membership"
            );
            assert!(!v.dataset.split.val[local] && !v.dataset.split.test[local]);
        }
        // every batch node is a training node of the full graph
        assert!(v.batch.iter().all(|&u| ds.split.train[u as usize]));
    }

    #[test]
    fn batch_covering_all_train_nodes_with_inf_fanout_is_the_training_halo() {
        // the S=0 equivalence fixture: batch = every training node,
        // fanout = inf per layer; the view is then the full k-hop closure
        // of the training set, with train masks matching the full graph's
        let ds = karate();
        let assign: Vec<u32> = (0..ds.n() as u32).map(|i| i % 2).collect();
        let n_train = ds.split.train.iter().filter(|&&t| t).count();
        let v =
            build_view(&ds, &assign, 2, &cfg(ds.n(), vec![Fanout::All, Fanout::All]), 9, 2).unwrap();
        assert_eq!(v.batch.len(), n_train, "oversized batch clamps to |train|");
        for (local, &gid) in v.nodes.iter().enumerate() {
            assert_eq!(v.dataset.split.train[local], ds.split.train[gid as usize]);
        }
    }
}
